//! Coreness (k-core) decomposition — §4.2: *minimize messaging* (hybrid
//! multicast/point-to-point) and *algorithmically prune computation*.
//!
//! The algorithm peels vertices of degree ≤ k in waves. A deleted vertex
//! must tell its neighbors to decrement their remaining degree; three
//! messaging disciplines are implemented:
//!
//! * [`MessageMode::P2p`] — send one point-to-point message per live
//!   neighbor (checking the shared deleted bitmap). Each send is a queue
//!   entry: cheap late (few live neighbors), expensive early (all
//!   neighbors live).
//! * [`MessageMode::Multicast`] — one multicast over the full neighbor
//!   list. One queue entry regardless of fan-out: cheap early, wasteful
//!   late (deliveries to already-deleted vertices are pure overhead).
//! * [`MessageMode::Hybrid`] — the paper's discipline: multicast while a
//!   vertex retains more than `switch_frac` (default 10 %) of its
//!   original degree, point-to-point after.
//!
//! **Pruning**: after a wave quiesces, the next k is jumped to the
//! minimum remaining degree instead of k+1 — the paper credits this alone
//! with an order of magnitude (Fig. 3).
//!
//! Messages are decrement *counts* (additively combinable), so the
//! engine routes them through dense combiner lanes: a vertex losing
//! several neighbors in one wave receives a single folded decrement —
//! under combining, `deliveries` counts touched destinations per round
//! (p2p still touches strictly fewer than multicast late in the peel,
//! because it skips already-deleted destinations entirely).

use std::sync::atomic::{AtomicU32, Ordering};

use crate::engine::{Combiner, Engine, EngineConfig, EndCtx, RunReport, VertexProgram, WorkerCtx};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::SharedVec;
use crate::VertexId;

/// Messaging discipline for deletion notifications.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MessageMode {
    /// Point-to-point to live neighbors only.
    P2p,
    /// Multicast over the full neighbor list.
    Multicast,
    /// Multicast above `switch_frac` of original degree, p2p below.
    Hybrid,
}

/// Coreness variants (what Fig. 3 compares).
#[derive(Debug, Clone, Copy)]
pub struct CorenessOptions {
    /// Messaging discipline.
    pub mode: MessageMode,
    /// Skip empty k levels (jump to min remaining degree).
    pub prune: bool,
    /// Hybrid switchover: fraction of original degree below which p2p is
    /// used (paper: 0.10).
    pub switch_frac: f64,
    /// Unoptimized activation: at each new k level, activate *every*
    /// live vertex (each fetches its edge list just to discover its
    /// degree is still above k) instead of only those at or below the
    /// peel level — the superfluous activation + I/O the event-driven
    /// version eliminates by keeping the degree in O(n) memory.
    pub scan_activation: bool,
}

impl CorenessOptions {
    /// The paper's unoptimized baseline: p2p, no pruning, scan
    /// activation at every level.
    pub fn unoptimized() -> Self {
        CorenessOptions {
            mode: MessageMode::P2p,
            prune: false,
            switch_frac: 0.1,
            scan_activation: true,
        }
    }

    /// Pruning only (multicast messaging, event-driven activation).
    pub fn pruned() -> Self {
        CorenessOptions {
            mode: MessageMode::Multicast,
            prune: true,
            switch_frac: 0.1,
            scan_activation: false,
        }
    }

    /// The full Graphyti discipline: pruning + hybrid messaging.
    pub fn graphyti() -> Self {
        CorenessOptions {
            mode: MessageMode::Hybrid,
            prune: true,
            switch_frac: 0.1,
            scan_activation: false,
        }
    }
}

struct Coreness {
    opts: CorenessOptions,
    /// Remaining degree (owner-updated in run_on_message).
    deg: SharedVec<u32>,
    /// Original degree (for the hybrid switchover).
    deg0: Vec<u32>,
    /// Coreness result; u32::MAX while live.
    core: SharedVec<u32>,
    /// Current peel level.
    k: AtomicU32,
    /// Live vertices remaining.
    remaining: AtomicU32,
}

impl Coreness {
    #[inline]
    fn deleted(&self, v: VertexId) -> bool {
        *self.core.get(v as usize) != u32::MAX
    }
}

impl VertexProgram for Coreness {
    // "decrement your degree by this many deleted neighbors" — a count
    // rather than a unit ping, so decrements to the same vertex fold by
    // addition in the combiner lanes (one delivery applies them all)
    type Msg = u32;

    fn combiner(&self) -> Option<Combiner<u32>> {
        Some(Combiner { identity: || 0, combine: |a, b| *a += *b })
    }

    fn edge_request(&self, v: VertexId) -> EdgeRequest {
        // a vertex only needs its neighbor list at deletion time
        if self.deleted(v) {
            EdgeRequest::None
        } else {
            EdgeRequest::Out
        }
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, u32>, v: VertexId, edges: &VertexEdges) {
        if self.deleted(v) {
            return;
        }
        let k = self.k.load(Ordering::Relaxed);
        let d = *self.deg.get(v as usize);
        if d > k {
            return; // activated speculatively, still above the peel level
        }
        // delete v at level k
        self.core.set(v as usize, k);
        self.remaining.fetch_sub(1, Ordering::Relaxed);
        let neighbors = &edges.out_neighbors;
        let use_p2p = match self.opts.mode {
            MessageMode::P2p => true,
            MessageMode::Multicast => false,
            MessageMode::Hybrid => {
                let d0 = self.deg0[v as usize] as f64;
                (d as f64) < self.opts.switch_frac * d0
            }
        };
        if use_p2p {
            // only live neighbors get a message (deleted bitmap is the
            // O(n) in-memory state that makes this filtering possible)
            for &u in neighbors {
                if !self.deleted(u) {
                    ctx.send(u, 1);
                }
            }
        } else {
            ctx.multicast(neighbors, 1);
        }
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, u32>, v: VertexId, m: &u32) {
        if self.deleted(v) {
            return; // wasted delivery — the cost multicast pays late
        }
        // `m` may be a folded batch of decrements from several deleted
        // neighbors; applying it at once is exactly the sum of applying
        // them one by one
        let d = self.deg.get_mut(v as usize);
        *d = d.saturating_sub(*m);
        if *d <= self.k.load(Ordering::Relaxed) {
            ctx.activate(v); // same-round cascade within the peel wave
        }
    }

    fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
        if !ctx.quiescent() {
            return; // wave still cascading
        }
        if self.remaining.load(Ordering::Relaxed) == 0 {
            return; // done: engine stops on quiescence
        }
        // wave for level k finished: advance k and seed the next wave
        let n = ctx.num_vertices();
        let next_k = if self.opts.prune {
            // jump to the minimum remaining degree (paper: an order of
            // magnitude from skipping empty levels)
            let mut min_deg = u32::MAX;
            for v in 0..n {
                if *self.core.get(v) == u32::MAX {
                    min_deg = min_deg.min(*self.deg.get(v));
                }
            }
            min_deg
        } else {
            self.k.load(Ordering::Relaxed) + 1
        };
        self.k.store(next_k, Ordering::Relaxed);
        let mut activated = false;
        for v in 0..n {
            if *self.core.get(v) == u32::MAX {
                // event-driven: only vertices at/below the peel level;
                // unoptimized: every live vertex re-checks itself
                if self.opts.scan_activation || *self.deg.get(v) <= next_k {
                    ctx.activate(v as VertexId);
                    activated = true;
                }
            }
        }
        if !activated {
            // empty k level: the unoptimized variant pays a full (empty)
            // BSP round for it — exactly the cost pruning eliminates
            ctx.force_continue();
        }
    }
}

/// Result of a coreness run.
pub struct CorenessResult {
    /// Coreness per vertex.
    pub core: Vec<u32>,
    /// Engine + I/O report.
    pub report: RunReport,
}

/// Run k-core decomposition on an undirected graph image.
pub fn coreness(
    source: &dyn EdgeSource,
    opts: CorenessOptions,
    cfg: &EngineConfig,
) -> CorenessResult {
    let index = source.index();
    assert!(!index.directed(), "coreness expects an undirected image");
    let n = index.num_vertices();
    let deg0: Vec<u32> = (0..n as VertexId).map(|v| index.out_deg(v)).collect();
    let prog = Coreness {
        opts,
        deg: SharedVec::from_vec(deg0.clone()),
        deg0,
        core: SharedVec::new(n, u32::MAX),
        k: AtomicU32::new(0),
        remaining: AtomicU32::new(n as u32),
    };
    // seed: everything with degree <= 0 (isolated) plus start the engine
    // with the full degree-0 set; the first iteration-end hook advances k.
    let init: Vec<VertexId> =
        (0..n as VertexId).filter(|&v| *prog.deg.get(v as usize) == 0).collect();
    let init = if init.is_empty() {
        // no isolated vertices: seed with min-degree set at its level
        let min_deg = (0..n).map(|v| *prog.deg.get(v)).min().unwrap();
        prog.k.store(min_deg, Ordering::Relaxed);
        (0..n as VertexId).filter(|&v| *prog.deg.get(v as usize) == min_deg).collect()
    } else {
        init
    };
    let report = Engine::run(&prog, source, &init, cfg);
    CorenessResult { core: prog.core.to_vec(), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    fn run_all_variants(n: usize, edges: &[(VertexId, VertexId)]) {
        let csr = Csr::from_edges(n, edges, false);
        let want = oracle::coreness(&csr);
        for (name, opts) in [
            ("unopt", CorenessOptions::unoptimized()),
            ("pruned", CorenessOptions::pruned()),
            ("graphyti", CorenessOptions::graphyti()),
        ] {
            let g = MemGraph::from_edges(n, edges, false);
            let got = coreness(&g, opts, &EngineConfig { workers: 4, ..Default::default() });
            assert_eq!(got.core, want, "variant {name}");
        }
    }

    #[test]
    fn clique_with_tail() {
        let mut edges = gen::complete(5);
        edges.push((4, 5));
        edges.push((5, 6));
        run_all_variants(7, &edges);
    }

    #[test]
    fn two_cliques_bridge() {
        run_all_variants(12, &gen::two_cliques(6));
    }

    #[test]
    fn rmat_graph() {
        let edges = gen::rmat(8, 2000, 17);
        run_all_variants(256, &edges);
    }

    #[test]
    fn grid_graph() {
        run_all_variants(64, &gen::grid_2d(8, 8));
    }

    #[test]
    fn pruning_reduces_rounds() {
        // a graph whose degrees have big gaps: pruning should skip levels
        let mut edges = gen::complete(20); // k-core 19 needs k up to 19
        edges.push((19, 20)); // tail of degree 1
        let g1 = MemGraph::from_edges(21, &edges, false);
        let unopt = coreness(&g1, CorenessOptions::unoptimized(), &EngineConfig::default());
        let g2 = MemGraph::from_edges(21, &edges, false);
        let pruned = coreness(&g2, CorenessOptions::pruned(), &EngineConfig::default());
        assert_eq!(unopt.core, pruned.core);
        assert!(
            pruned.report.rounds < unopt.report.rounds,
            "pruned {} rounds vs unopt {}",
            pruned.report.rounds,
            unopt.report.rounds
        );
    }

    #[test]
    fn hybrid_sends_fewer_deliveries_than_multicast_late() {
        // heavy-tailed graph: late in the peel most neighbors are deleted,
        // so hybrid should deliver fewer messages than pure multicast
        let edges = gen::rmat(9, 6000, 23);
        let g1 = MemGraph::from_edges(512, &edges, false);
        let multi = coreness(&g1, CorenessOptions::pruned(), &EngineConfig::default());
        let g2 = MemGraph::from_edges(512, &edges, false);
        let hybrid = coreness(&g2, CorenessOptions::graphyti(), &EngineConfig::default());
        assert_eq!(multi.core, hybrid.core);
        assert!(
            hybrid.report.engine.deliveries < multi.report.engine.deliveries,
            "hybrid {} deliveries vs multicast {}",
            hybrid.report.engine.deliveries,
            multi.report.engine.deliveries
        );
    }
}
