//! Scan statistic (scan-1) — a FlashGraph library member (Priebe's
//! locality statistic, used for chatter-anomaly detection): for each
//! vertex, the number of edges in its closed 1-neighborhood,
//! `SS(v) = deg(v) + |{(u,w) ∈ E : u,w ∈ N(v)}|`.
//!
//! Same SEM access pattern as triangle counting (§4.5) — each vertex
//! intersects neighbor lists — and it reuses the same in-memory
//! optimizations (sorted lists, restarted binary search).

use crate::engine::{Engine, EngineConfig, RunReport, VertexProgram, WorkerCtx};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::SharedVec;
use crate::VertexId;

struct ScanStat {
    stat: SharedVec<u64>,
}

impl VertexProgram for ScanStat {
    type Msg = ();

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, edges: &VertexEdges) {
        let nbrs = &edges.out_neighbors;
        let mut edges_in_hood = 0u64;
        // count each neighbor-pair edge once: for u in N(v), count
        // w ∈ N(u) ∩ N(v) with w > u (both lists sorted ascending)
        for &u in nbrs {
            let nu = ctx.fetch_edges(u, EdgeRequest::Out);
            // restarted binary search over the suffix (§4.5 optimization)
            let start = match nbrs.binary_search(&u) {
                Ok(p) | Err(p) => p + 1,
            };
            let tail = &nbrs[start.min(nbrs.len())..];
            let mut lo = 0usize;
            for &w in tail {
                match nu.out_neighbors[lo..].binary_search(&w) {
                    Ok(p) => {
                        edges_in_hood += 1;
                        lo += p + 1;
                    }
                    Err(p) => lo += p,
                }
                if lo >= nu.out_neighbors.len() {
                    break;
                }
            }
        }
        self.stat.set(v as usize, nbrs.len() as u64 + edges_in_hood);
    }

    fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
}

/// Per-vertex scan-1 statistic on an undirected image, plus the maximum
/// (the anomaly score) and the run report.
pub fn scan_statistic(
    source: &dyn EdgeSource,
    cfg: &EngineConfig,
) -> (Vec<u64>, (VertexId, u64), RunReport) {
    let index = source.index();
    assert!(!index.directed(), "scan statistic expects an undirected image");
    let n = index.num_vertices();
    let prog = ScanStat { stat: SharedVec::new(n, 0u64) };
    let all: Vec<VertexId> = (0..n as VertexId).collect();
    let report = Engine::run(&prog, source, &all, cfg);
    let stat = prog.stat.into_vec();
    let max = stat
        .iter()
        .enumerate()
        .max_by_key(|&(_, &s)| s)
        .map(|(v, &s)| (v as VertexId, s))
        .unwrap_or((0, 0));
    (stat, max, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    /// Oracle: brute-force edges within the closed neighborhood.
    fn oracle_scan(g: &Csr) -> Vec<u64> {
        let n = g.num_vertices();
        (0..n as VertexId)
            .map(|v| {
                let nbrs = g.out(v);
                let mut c = nbrs.len() as u64;
                for (i, &u) in nbrs.iter().enumerate() {
                    for &w in &nbrs[i + 1..] {
                        if g.out(u).binary_search(&w).is_ok() {
                            c += 1;
                        }
                    }
                }
                c
            })
            .collect()
    }

    #[test]
    fn matches_oracle_on_known_shapes() {
        // K4: SS(v) = 3 + C(3,2) = 6 for every vertex
        let g = MemGraph::from_edges(4, &gen::complete(4), false);
        let (stat, max, _) = scan_statistic(&g, &EngineConfig::default());
        assert_eq!(stat, vec![6, 6, 6, 6]);
        assert_eq!(max.1, 6);
        // path: interior SS = 2, ends SS = 1
        let g = MemGraph::from_edges(5, &gen::path(5), false);
        let (stat, _, _) = scan_statistic(&g, &EngineConfig::default());
        assert_eq!(stat, vec![1, 2, 2, 2, 1]);
    }

    #[test]
    fn matches_oracle_on_rmat() {
        let edges = gen::rmat(8, 2000, 99);
        let g = MemGraph::from_edges(256, &edges, false);
        let csr = Csr::from_edges(256, &edges, false);
        let (stat, max, _) = scan_statistic(&g, &EngineConfig { workers: 4, ..Default::default() });
        assert_eq!(stat, oracle_scan(&csr));
        assert_eq!(max.1, *stat.iter().max().unwrap());
    }

    #[test]
    fn detects_planted_clique() {
        // sparse ring + a planted K8: the clique members dominate SS
        let mut edges = gen::cycle(200);
        for u in 0..8u32 {
            for v in (u + 1)..8 {
                edges.push((u * 20, v * 20)); // spread through the ring
            }
        }
        let g = MemGraph::from_edges(200, &edges, false);
        let (_, max, _) = scan_statistic(&g, &EngineConfig::default());
        assert_eq!(max.0 % 20, 0, "anomaly must be a clique member, got v{}", max.0);
        assert!(max.1 >= 28, "clique edges must dominate: {}", max.1);
    }
}
