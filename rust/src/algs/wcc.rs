//! Weakly connected components by min-label propagation (library extra).

use crate::engine::{
    CheckpointImage, CheckpointWriter, Combiner, Engine, EngineConfig, RunReport, VertexProgram,
    WorkerCtx,
};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::SharedVec;
use crate::VertexId;

struct Wcc {
    label: SharedVec<VertexId>,
}

impl VertexProgram for Wcc {
    type Msg = VertexId; // proposed component label

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        // weak connectivity: propagate along both directions
        EdgeRequest::Both
    }

    // min-label propagation: only the smallest proposed label matters,
    // so labels to the same destination fold to their minimum
    fn combiner(&self) -> Option<Combiner<VertexId>> {
        Some(Combiner { identity: || VertexId::MAX, combine: |a, b| *a = (*a).min(*b) })
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, VertexId>, v: VertexId, edges: &VertexEdges) {
        let l = *self.label.get(v as usize);
        ctx.multicast(&edges.out_neighbors, l);
        ctx.multicast(&edges.in_neighbors, l);
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, VertexId>, v: VertexId, l: &VertexId) {
        let cur = self.label.get_mut(v as usize);
        if *l < *cur {
            *cur = *l;
            ctx.activate(v);
        }
    }

    fn supports_pull(&self) -> bool {
        true
    }

    fn pull_request(&self) -> EdgeRequest {
        // push multicasts along out- AND in-lists, i.e. across every
        // incident edge — the pull sweep must traverse the same set
        EdgeRequest::Both
    }

    fn pull_message(&self, src: VertexId, _dst: VertexId) -> Option<VertexId> {
        // labels are written only in phase A (run_on_message), so the
        // value an active src would have multicast is stable here
        Some(*self.label.get(src as usize))
    }

    // min-label propagation is order-independent integer folding, so a
    // resumed run is bit-identical at any worker count
    fn checkpointable(&self) -> bool {
        true
    }

    fn checkpoint_save(&self, w: &mut CheckpointWriter) {
        w.put_u32("label", &self.label);
    }

    fn checkpoint_restore(&self, img: &CheckpointImage) -> crate::Result<()> {
        img.restore_u32("label", &self.label)
    }
}

/// Component label (min reachable vertex id) per vertex.
pub fn wcc(source: &dyn EdgeSource, cfg: &EngineConfig) -> (Vec<VertexId>, RunReport) {
    let n = source.index().num_vertices();
    let prog = Wcc { label: SharedVec::from_vec((0..n as VertexId).collect()) };
    let all: Vec<VertexId> = (0..n as VertexId).collect();
    let report = Engine::run(&prog, source, &all, cfg);
    (prog.label.into_vec(), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    #[test]
    fn matches_oracle_multi_component() {
        // 3 components with directed edges
        let edges = vec![(0u32, 1u32), (1, 2), (5, 4), (4, 3), (7, 8)];
        let g = MemGraph::from_edges(9, &edges, true);
        let csr = Csr::from_edges(9, &edges, true);
        let (got, _) = wcc(&g, &EngineConfig { workers: 3, ..Default::default() });
        assert_eq!(got, oracle::wcc(&csr));
    }

    #[test]
    fn matches_oracle_on_rmat() {
        let edges = gen::rmat(9, 2500, 13);
        let g = MemGraph::from_edges(512, &edges, true);
        let csr = Csr::from_edges(512, &edges, true);
        let (got, _) = wcc(&g, &EngineConfig::default());
        assert_eq!(got, oracle::wcc(&csr));
    }

    #[test]
    fn singleton_components_keep_own_label() {
        let g = MemGraph::from_edges(4, &[(0, 1)], true);
        let (got, _) = wcc(&g, &EngineConfig::default());
        assert_eq!(got, vec![0, 0, 2, 3]);
    }
}
