//! Louvain community detection — §4.6: *avoid graph structure
//! modification*.
//!
//! Louvain alternates **local-move** phases (each vertex greedily joins
//! the neighboring community with maximal positive modularity gain) with
//! **aggregation** phases that coarsen communities into super-vertices.
//! Aggregation is where SEM implementations diverge:
//!
//! * [`LouvainMode::Graphyti`] — never rewrites the graph. Aggregation
//!   produces *metadata only*: a vertex→community index plus an in-memory
//!   weighted community adjacency (hash-based), and message routing keeps
//!   working through the index ("lazy deletion + community
//!   representative"). Cost: one streaming read of the edge data.
//! * [`LouvainMode::Physical`] — the paper's best-case baseline for a
//!   physically-modifying implementation: each aggregation **materializes
//!   a new packed graph image in RAM** (the RAMDisk stand-in: sort,
//!   dedup-accumulate, pack — everything a rewrite pays except disk write
//!   throughput; DESIGN.md §5).
//!
//! Both modes run the identical level-0 local-move phase vertex-centric
//! over the SEM image, so the measured difference is purely the
//! aggregation strategy (Fig. 8).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::engine::{Engine, EngineConfig, EndCtx, RunReport, VertexProgram, WorkerCtx};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::atomic_f64::{atomic_f64_vec, AtomicF64};
use crate::util::SharedVec;
use crate::VertexId;

/// Aggregation strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LouvainMode {
    /// Metadata-only aggregation (the paper's contribution).
    Graphyti,
    /// Materialize a packed graph image per level (best-case rewrite).
    Physical,
}

/// Result of a Louvain run.
pub struct LouvainResult {
    /// Final community per level-0 vertex (labels are arbitrary ids).
    pub community: Vec<VertexId>,
    /// Final modularity Q.
    pub modularity: f64,
    /// Number of levels executed (including level 0).
    pub levels: usize,
    /// Time in local-move phases.
    pub local_move_wall: Duration,
    /// Time in aggregation phases (the Fig. 8a breakdown).
    pub aggregate_wall: Duration,
    /// Level-0 engine report.
    pub report: RunReport,
}

// ------------------------------------------------- level-0 local moves --

// Level-0 pings carry no foldable value (the information is "someone
// near you moved", and the handler just re-activates), so Louvain stays
// on the queue lanes, where a whole neighborhood ping is one multicast
// entry per destination worker — declaring a trivial combiner would buy
// nothing the Multi entry doesn't already provide.
struct LouvainL0 {
    /// Current community of each vertex (racy cross-reads are fine for
    /// the greedy heuristic; own-slot writes are claimant-exclusive).
    community: SharedVec<VertexId>,
    /// Σ of weighted degrees per community (concurrent moves).
    comm_tot: Vec<AtomicF64>,
    /// Weighted degree of each vertex (unit weights at level 0).
    k: Vec<f64>,
    /// Total weight × 2 (= stored edge count for undirected unit graphs).
    m2: f64,
    /// Local-move pass cap.
    max_rounds: usize,
}

impl VertexProgram for LouvainL0 {
    type Msg = (); // "reconsider your community" ping

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, edges: &VertexEdges) {
        let cur = *self.community.get(v as usize);
        let kv = self.k[v as usize];
        // weight of v's links into each neighboring community
        let mut links: HashMap<VertexId, f64> = HashMap::new();
        for &u in &edges.out_neighbors {
            *links.entry(*self.community.get(u as usize)).or_default() += 1.0;
        }
        // score(c) = k_{v,c} - Σtot(c)·k_v/m2, with v removed from `cur`
        let score = |c: VertexId, link_w: f64| {
            let mut tot = self.comm_tot[c as usize].load();
            if c == cur {
                tot -= kv;
            }
            link_w - tot * kv / self.m2
        };
        let mut best = (cur, score(cur, links.get(&cur).copied().unwrap_or(0.0)));
        for (&c, &w) in &links {
            if c == cur {
                continue;
            }
            let s = score(c, w);
            // strict improvement, ties toward smaller id (oscillation damper)
            if s > best.1 + 1e-12 || (s > best.1 - 1e-12 && c < best.0) {
                best = (c, s);
            }
        }
        if best.0 != cur && best.1 > score(cur, links.get(&cur).copied().unwrap_or(0.0)) + 1e-12 {
            self.comm_tot[cur as usize].fetch_add(-kv);
            self.comm_tot[best.0 as usize].fetch_add(kv);
            self.community.set(v as usize, best.0);
            // neighbors' best choices may have changed
            ctx.multicast(&edges.out_neighbors, ());
        }
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, _m: &()) {
        ctx.activate(v);
    }

    fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
        if ctx.round() + 1 >= self.max_rounds {
            ctx.stop();
        }
    }
}

// ------------------------------------------------ coarse representations --

/// Weighted coarse graph: hash-based (Graphyti metadata aggregation).
struct MetaCoarse {
    adj: Vec<HashMap<u32, f64>>,
    /// Self-loop weight per community (intra-community edge mass).
    selfw: Vec<f64>,
    k: Vec<f64>,
    m2: f64,
}

/// Weighted coarse graph: packed image (physical materialization).
/// Layout per vertex: `[(neighbor u32, weight f32) × deg]` — the RAMDisk
/// byte image a rewriting implementation would produce.
struct PackedCoarse {
    offsets: Vec<usize>,
    bytes: Vec<u8>,
    selfw: Vec<f64>,
    k: Vec<f64>,
    m2: f64,
}

/// Uniform access for the in-memory refinement levels.
trait Coarse {
    fn num(&self) -> usize;
    fn k(&self, c: u32) -> f64;
    fn selfw(&self, c: u32) -> f64;
    fn m2(&self) -> f64;
    fn for_neighbors(&self, c: u32, f: &mut dyn FnMut(u32, f64));
}

impl Coarse for MetaCoarse {
    fn num(&self) -> usize {
        self.adj.len()
    }
    fn k(&self, c: u32) -> f64 {
        self.k[c as usize]
    }
    fn selfw(&self, c: u32) -> f64 {
        self.selfw[c as usize]
    }
    fn m2(&self) -> f64 {
        self.m2
    }
    fn for_neighbors(&self, c: u32, f: &mut dyn FnMut(u32, f64)) {
        for (&u, &w) in &self.adj[c as usize] {
            f(u, w);
        }
    }
}

impl Coarse for PackedCoarse {
    fn num(&self) -> usize {
        self.offsets.len() - 1
    }
    fn k(&self, c: u32) -> f64 {
        self.k[c as usize]
    }
    fn selfw(&self, c: u32) -> f64 {
        self.selfw[c as usize]
    }
    fn m2(&self) -> f64 {
        self.m2
    }
    fn for_neighbors(&self, c: u32, f: &mut dyn FnMut(u32, f64)) {
        let lo = self.offsets[c as usize];
        let hi = self.offsets[c as usize + 1];
        let rec = &self.bytes[lo..hi];
        for e in rec.chunks_exact(8) {
            let u = u32::from_le_bytes(e[..4].try_into().unwrap());
            let w = f32::from_le_bytes(e[4..].try_into().unwrap());
            f(u, w as f64);
        }
    }
}

/// Renumber communities densely; returns (mapping old→new, count).
fn renumber(assign: &[u32]) -> (Vec<u32>, usize) {
    let mut map = HashMap::new();
    let mut out = Vec::with_capacity(assign.len());
    for &c in assign {
        let next = map.len() as u32;
        out.push(*map.entry(c).or_insert(next));
    }
    (out, map.len())
}

/// Build weighted coarse edges `(cu, cv, w)` from a coarse graph + a dense
/// community assignment over its vertices.
fn coarse_edges(g: &dyn Coarse, assign: &[u32], nc: usize) -> (Vec<HashMap<u32, f64>>, Vec<f64>, Vec<f64>) {
    let mut adj: Vec<HashMap<u32, f64>> = vec![HashMap::new(); nc];
    let mut selfw = vec![0.0f64; nc];
    let mut k = vec![0.0f64; nc];
    for v in 0..g.num() as u32 {
        let cv = assign[v as usize];
        k[cv as usize] += g.k(v);
        // intra mass of the merged vertex carries over
        selfw[cv as usize] += g.selfw(v);
        g.for_neighbors(v, &mut |u, w| {
            let cu = assign[u as usize];
            if cu == cv {
                // each undirected edge visited from both endpoints
                selfw[cv as usize] += w / 2.0;
            } else {
                *adj[cv as usize].entry(cu).or_default() += w;
            }
        });
    }
    (adj, selfw, k)
}

/// One sequential local-move pass set over a coarse graph. Returns the
/// assignment (dense ids) and how many moves happened.
fn refine(g: &dyn Coarse, max_passes: usize) -> (Vec<u32>, usize) {
    let n = g.num();
    let mut assign: Vec<u32> = (0..n as u32).collect();
    let mut tot: Vec<f64> = (0..n as u32).map(|c| g.k(c)).collect();
    let mut total_moves = 0;
    for _ in 0..max_passes {
        let mut moves = 0;
        for v in 0..n as u32 {
            let cur = assign[v as usize];
            let kv = g.k(v);
            let mut links: HashMap<u32, f64> = HashMap::new();
            g.for_neighbors(v, &mut |u, w| {
                *links.entry(assign[u as usize]).or_default() += w;
            });
            let m2 = g.m2();
            let score = |c: u32, w: f64, tot: &[f64]| {
                let mut t = tot[c as usize];
                if c == cur {
                    t -= kv;
                }
                w - t * kv / m2
            };
            let cur_score = score(cur, links.get(&cur).copied().unwrap_or(0.0), &tot);
            let mut best = (cur, cur_score);
            for (&c, &w) in &links {
                if c == cur {
                    continue;
                }
                let s = score(c, w, &tot);
                if s > best.1 + 1e-12 || (s > best.1 - 1e-12 && c < best.0) {
                    best = (c, s);
                }
            }
            if best.0 != cur {
                tot[cur as usize] -= kv;
                tot[best.0 as usize] += kv;
                assign[v as usize] = best.0;
                moves += 1;
            }
        }
        total_moves += moves;
        if moves == 0 {
            break;
        }
    }
    (assign, total_moves)
}

/// Modularity of the identity partition of a coarse graph (each coarse
/// vertex = one community).
fn coarse_modularity(g: &dyn Coarse) -> f64 {
    let m2 = g.m2();
    if m2 == 0.0 {
        return 0.0;
    }
    let mut q = 0.0;
    for c in 0..g.num() as u32 {
        q += 2.0 * g.selfw(c) / m2 - (g.k(c) / m2) * (g.k(c) / m2);
    }
    q
}

// -------------------------------------------------------------- driver --

/// Run Louvain. `max_levels` bounds coarsening depth (level 0 included).
pub fn louvain(
    source: &dyn EdgeSource,
    mode: LouvainMode,
    max_levels: usize,
    cfg: &EngineConfig,
) -> LouvainResult {
    let index = source.index();
    assert!(!index.directed(), "louvain expects an undirected image");
    let n = index.num_vertices();
    let m2 = index.num_edges() as f64;

    // ---- level 0: vertex-centric local moves over the SEM image -------
    let t_local = Instant::now();
    let prog = LouvainL0 {
        community: SharedVec::from_vec((0..n as VertexId).collect()),
        comm_tot: atomic_f64_vec(n, 0.0),
        k: (0..n as VertexId).map(|v| index.out_deg(v) as f64).collect(),
        m2: m2.max(1.0),
        max_rounds: 64,
    };
    for v in 0..n as VertexId {
        prog.comm_tot[v as usize].store(index.out_deg(v) as f64);
    }
    let all: Vec<VertexId> = (0..n as VertexId).collect();
    let report = Engine::run(&prog, source, &all, cfg);
    let mut local_move_wall = t_local.elapsed();

    let (l0_assign, _) = renumber(&prog.community.to_vec());
    let nc0 = l0_assign.iter().map(|&c| c as usize + 1).max().unwrap_or(1);
    let mut mapping: Vec<u32> = l0_assign.clone(); // level-0 vertex -> current community

    // ---- level-0 aggregation: stream the edge data once ----------------
    // Graphyti: fold edges straight into per-community hash metadata (one
    // streaming pass, no rewrite). Physical: materialize the *relabeled
    // edge list* exactly as a rewriting implementation must — collect all
    // O(m) coarse endpoints, globally sort, dedup-accumulate and pack a
    // new image (in RAM = the paper's RAMDisk best case).
    let mut aggregate_wall = Duration::ZERO;
    let t_agg = Instant::now();
    let mut coarse: Box<dyn Coarse> = match mode {
        LouvainMode::Graphyti => {
            let mut adj: Vec<HashMap<u32, f64>> = vec![HashMap::new(); nc0];
            let mut selfw = vec![0.0f64; nc0];
            let mut k = vec![0.0f64; nc0];
            stream_edges(source, n, |v, u| {
                let (cv, cu) = (l0_assign[v as usize], l0_assign[u as usize]);
                k[cv as usize] += 1.0;
                if cu == cv {
                    selfw[cv as usize] += 0.5;
                } else {
                    *adj[cv as usize].entry(cu).or_default() += 1.0;
                }
            });
            Box::new(MetaCoarse { adj, selfw, k, m2 })
        }
        LouvainMode::Physical => {
            let mut relabeled: Vec<(u32, u32)> = Vec::with_capacity(m2 as usize);
            let mut selfw = vec![0.0f64; nc0];
            let mut k = vec![0.0f64; nc0];
            stream_edges(source, n, |v, u| {
                let (cv, cu) = (l0_assign[v as usize], l0_assign[u as usize]);
                k[cv as usize] += 1.0;
                if cu == cv {
                    selfw[cv as usize] += 0.5;
                } else {
                    relabeled.push((cv, cu));
                }
            });
            Box::new(pack_relabeled(relabeled, selfw, k, nc0, m2))
        }
    };
    aggregate_wall += t_agg.elapsed();

    // ---- higher levels: in-memory refinement + per-mode aggregation ---
    let mut levels = 1;
    let mut q = coarse_modularity(coarse.as_ref());
    while levels < max_levels {
        let t = Instant::now();
        let (assign, moves) = refine(coarse.as_ref(), 16);
        local_move_wall += t.elapsed();
        if moves == 0 {
            break;
        }
        let (dense, nc) = renumber(&assign);
        // compose the level mapping down to level-0 vertices
        for m in mapping.iter_mut() {
            *m = dense[*m as usize];
        }
        let t = Instant::now();
        let (adj, selfw, k) = coarse_edges(coarse.as_ref(), &dense, nc);
        coarse = match mode {
            LouvainMode::Graphyti => Box::new(MetaCoarse { adj, selfw, k, m2 }),
            LouvainMode::Physical => Box::new(pack_coarse(adj, selfw, k, m2)),
        };
        aggregate_wall += t.elapsed();
        levels += 1;
        let q_new = coarse_modularity(coarse.as_ref());
        if q_new <= q + 1e-9 {
            q = q_new.max(q);
            break;
        }
        q = q_new;
    }

    LouvainResult {
        community: mapping.iter().map(|&c| c as VertexId).collect(),
        modularity: q,
        levels,
        local_move_wall,
        aggregate_wall,
        report,
    }
}

/// One streaming pass over all edge lists (the O(m) aggregation read).
fn stream_edges(source: &dyn EdgeSource, n: usize, mut f: impl FnMut(VertexId, VertexId)) {
    let batch = 1024;
    let mut v0 = 0usize;
    while v0 < n {
        let hi = (v0 + batch).min(n);
        let reqs: Vec<(VertexId, EdgeRequest)> =
            (v0..hi).map(|v| (v as VertexId, EdgeRequest::Out)).collect();
        let edges = source.fetch_batch(&reqs).expect("aggregation scan failed");
        for (i, e) in edges.iter().enumerate() {
            let v = (v0 + i) as VertexId;
            for &u in &e.out_neighbors {
                f(v, u);
            }
        }
        v0 = hi;
    }
}

/// The physical rewrite: globally sort the relabeled edge list,
/// dedup-accumulate weights, pack a new byte image (RAMDisk best case).
fn pack_relabeled(
    mut relabeled: Vec<(u32, u32)>,
    selfw: Vec<f64>,
    k: Vec<f64>,
    nc: usize,
    m2: f64,
) -> PackedCoarse {
    relabeled.sort_unstable();
    let mut offsets = Vec::with_capacity(nc + 1);
    let mut bytes = Vec::new();
    offsets.push(0);
    let mut i = 0usize;
    for c in 0..nc as u32 {
        while i < relabeled.len() && relabeled[i].0 == c {
            // accumulate duplicate (c, u) runs into one weighted edge
            let u = relabeled[i].1;
            let mut w = 0f32;
            while i < relabeled.len() && relabeled[i] == (c, u) {
                w += 1.0;
                i += 1;
            }
            bytes.extend_from_slice(&u.to_le_bytes());
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        offsets.push(bytes.len());
    }
    PackedCoarse { offsets, bytes, selfw, k, m2 }
}

/// Materialize a packed coarse image: sort + pack — everything a physical
/// rewrite pays except the disk write itself (RAMDisk best case).
fn pack_coarse(
    adj: Vec<HashMap<u32, f64>>,
    selfw: Vec<f64>,
    k: Vec<f64>,
    m2: f64,
) -> PackedCoarse {
    let n = adj.len();
    let mut offsets = Vec::with_capacity(n + 1);
    let mut bytes = Vec::new();
    offsets.push(0);
    for nbrs in &adj {
        let mut sorted: Vec<(u32, f64)> = nbrs.iter().map(|(&u, &w)| (u, w)).collect();
        sorted.sort_unstable_by_key(|&(u, _)| u);
        for (u, w) in sorted {
            bytes.extend_from_slice(&u.to_le_bytes());
            bytes.extend_from_slice(&(w as f32).to_le_bytes());
        }
        offsets.push(bytes.len());
    }
    PackedCoarse { offsets, bytes, selfw, k, m2 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    fn communities_of(result: &LouvainResult) -> usize {
        let mut cs: Vec<VertexId> = result.community.clone();
        cs.sort_unstable();
        cs.dedup();
        cs.len()
    }

    #[test]
    fn two_cliques_found_both_modes() {
        let edges = gen::two_cliques(8);
        for mode in [LouvainMode::Graphyti, LouvainMode::Physical] {
            let g = MemGraph::from_edges(16, &edges, false);
            let r = louvain(&g, mode, 8, &EngineConfig { workers: 2, ..Default::default() });
            assert_eq!(communities_of(&r), 2, "{mode:?}");
            // all of clique 1 together, all of clique 2 together
            for v in 1..8 {
                assert_eq!(r.community[v], r.community[0], "{mode:?}");
            }
            for v in 9..16 {
                assert_eq!(r.community[v], r.community[8], "{mode:?}");
            }
            assert!(r.modularity > 0.4, "{mode:?} Q={}", r.modularity);
        }
    }

    #[test]
    fn modularity_agrees_with_oracle_formula() {
        let edges = gen::two_cliques(10);
        let g = MemGraph::from_edges(20, &edges, false);
        let r = louvain(&g, LouvainMode::Graphyti, 8, &EngineConfig::default());
        let csr = Csr::from_edges(20, &edges, false);
        let q_oracle = oracle::modularity(&csr, &r.community);
        assert!(
            (r.modularity - q_oracle).abs() < 1e-9,
            "internal Q {} vs oracle {}",
            r.modularity,
            q_oracle
        );
    }

    #[test]
    fn ring_of_cliques() {
        // 4 cliques of 5, ring-connected: canonical Louvain fixture
        let mut edges = Vec::new();
        let k = 5;
        for c in 0..4u32 {
            let base = c * k;
            for i in 0..k {
                for j in (i + 1)..k {
                    edges.push((base + i, base + j));
                }
            }
            let next_base = ((c + 1) % 4) * k;
            edges.push((base, next_base));
        }
        for mode in [LouvainMode::Graphyti, LouvainMode::Physical] {
            let g = MemGraph::from_edges(20, &edges, false);
            let r = louvain(&g, mode, 8, &EngineConfig::default());
            assert_eq!(communities_of(&r), 4, "{mode:?}");
            assert!(r.modularity > 0.5, "{mode:?} Q={}", r.modularity);
        }
    }

    #[test]
    fn modularity_positive_on_rmat() {
        let edges = gen::rmat(9, 3000, 101);
        let g = MemGraph::from_edges(512, &edges, false);
        let r = louvain(&g, LouvainMode::Graphyti, 10, &EngineConfig::default());
        // power-law graphs still have community structure vs random
        assert!(r.modularity > 0.1, "Q={}", r.modularity);
        let csr = Csr::from_edges(512, &edges, false);
        let q_oracle = oracle::modularity(&csr, &r.community);
        assert!((r.modularity - q_oracle).abs() < 1e-6);
    }

    #[test]
    fn both_modes_reach_similar_quality() {
        let edges = gen::rmat(8, 1500, 7);
        let g1 = MemGraph::from_edges(256, &edges, false);
        let a = louvain(&g1, LouvainMode::Graphyti, 10, &EngineConfig::default());
        let g2 = MemGraph::from_edges(256, &edges, false);
        let b = louvain(&g2, LouvainMode::Physical, 10, &EngineConfig::default());
        assert!((a.modularity - b.modularity).abs() < 0.05, "Q {} vs {}", a.modularity, b.modularity);
    }
}
