//! Breadth-first search: uni-source (levels) and multi-source (lane
//! bitmaps) — the building block of §4.3 diameter estimation.
//!
//! **Multi-source BFS** runs up to 64 concurrent searches, one bit lane
//! per source, in lockstep rounds: a vertex holds a `u64` mask of the
//! searches that have reached it, and frontier expansion ORs masks across
//! edges. Because many lanes activate the *same* vertices within a round,
//! each fetched edge list is reused by every lane on it — the page-cache
//! reuse the paper credits for multi-source speedups (Figs. 4–5).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::engine::{Combiner, Engine, EngineConfig, EndCtx, RunReport, VertexProgram, WorkerCtx};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::SharedVec;
use crate::VertexId;

// ------------------------------------------------------------ uni-source

struct UniBfs {
    level: SharedVec<i64>,
}

impl VertexProgram for UniBfs {
    type Msg = i64; // proposed level

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out
    }

    // proposed levels fold to their minimum
    fn combiner(&self) -> Option<Combiner<i64>> {
        Some(Combiner { identity: || i64::MAX, combine: |a, b| *a = (*a).min(*b) })
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, i64>, v: VertexId, edges: &VertexEdges) {
        ctx.multicast(&edges.out_neighbors, *self.level.get(v as usize) + 1);
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, i64>, v: VertexId, lvl: &i64) {
        let cur = self.level.get_mut(v as usize);
        if *cur < 0 {
            *cur = *lvl;
            ctx.activate(v);
        }
    }

    fn supports_pull(&self) -> bool {
        true
    }

    fn pull_message(&self, src: VertexId, _dst: VertexId) -> Option<i64> {
        // level[src] is written only in run_on_message (phase A), so it
        // is stable through phase B — exactly what a push round's
        // multicast would have carried
        Some(*self.level.get(src as usize) + 1)
    }
}

/// BFS levels from `src` (-1 = unreachable), plus the run report.
pub fn bfs(source: &dyn EdgeSource, src: VertexId, cfg: &EngineConfig) -> (Vec<i64>, RunReport) {
    let n = source.index().num_vertices();
    let prog = UniBfs { level: SharedVec::new(n, -1) };
    prog.level.set(src as usize, 0);
    let report = Engine::run(&prog, source, &[src], cfg);
    (prog.level.into_vec(), report)
}

// ----------------------------------------------------------- multi-source

/// Multi-source BFS program (≤ 64 sources; one bit lane each).
pub struct MsBfs {
    num_lanes: usize,
    /// Mask of lanes that have reached each vertex.
    visited: SharedVec<u64>,
    /// Lanes gained since the vertex last ran (the frontier payload).
    gained: SharedVec<u64>,
    /// Lanes that reached any new vertex this round.
    progress: AtomicU64,
    /// Per-lane eccentricity: last round with progress.
    ecc: Mutex<Vec<i64>>,
}

impl MsBfs {
    /// Build for the given sources (≤ 64).
    pub fn new(n: usize, sources: &[VertexId]) -> Self {
        assert!(!sources.is_empty() && sources.len() <= 64, "1..=64 sources");
        let prog = MsBfs {
            num_lanes: sources.len(),
            visited: SharedVec::new(n, 0u64),
            gained: SharedVec::new(n, 0u64),
            progress: AtomicU64::new(0),
            ecc: Mutex::new(vec![0i64; sources.len()]),
        };
        for (lane, &s) in sources.iter().enumerate() {
            *prog.visited.get_mut(s as usize) |= 1 << lane;
            *prog.gained.get_mut(s as usize) |= 1 << lane;
        }
        prog
    }

    /// Per-lane eccentricities after the run.
    pub fn eccentricities(&self) -> Vec<i64> {
        self.ecc.lock().unwrap().clone()
    }

    /// Visited mask per vertex after the run.
    pub fn visited_masks(&self) -> Vec<u64> {
        self.visited.to_vec()
    }
}

impl VertexProgram for MsBfs {
    type Msg = u64; // lane mask

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out
    }

    // lane masks union: the diameter-estimation bitsets are the
    // textbook OR-combinable message
    fn combiner(&self) -> Option<Combiner<u64>> {
        Some(Combiner { identity: || 0, combine: |a, b| *a |= *b })
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, u64>, v: VertexId, edges: &VertexEdges) {
        let g = std::mem::take(self.gained.get_mut(v as usize));
        if g != 0 {
            ctx.multicast(&edges.out_neighbors, g);
        }
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, u64>, v: VertexId, mask: &u64) {
        let vis = self.visited.get_mut(v as usize);
        let new = mask & !*vis;
        if new != 0 {
            *vis |= new;
            *self.gained.get_mut(v as usize) |= new;
            self.progress.fetch_or(new, Ordering::Relaxed);
            ctx.activate(v); // same round: lockstep level = round
        }
    }

    fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
        let prog = self.progress.swap(0, Ordering::Relaxed);
        if prog != 0 {
            let mut ecc = self.ecc.lock().unwrap();
            for (lane, e) in ecc.iter_mut().enumerate().take(self.num_lanes) {
                if prog & (1 << lane) != 0 {
                    *e = ctx.round() as i64;
                }
            }
        }
    }
}

/// Run multi-source BFS; returns per-lane eccentricities and the report.
pub fn ms_bfs(
    source: &dyn EdgeSource,
    sources: &[VertexId],
    cfg: &EngineConfig,
) -> (Vec<i64>, RunReport) {
    let n = source.index().num_vertices();
    let prog = MsBfs::new(n, sources);
    let report = Engine::run(&prog, source, sources, cfg);
    (prog.eccentricities(), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    #[test]
    fn uni_bfs_matches_oracle() {
        let edges = gen::rmat(8, 1500, 2);
        let g = MemGraph::from_edges(256, &edges, true);
        let csr = Csr::from_edges(256, &edges, true);
        let (got, _) = bfs(&g, 0, &EngineConfig::default());
        assert_eq!(got, oracle::bfs_levels(&csr, 0));
    }

    #[test]
    fn ms_bfs_ecc_matches_oracle_each_lane() {
        let edges = gen::rmat(8, 1200, 4);
        let n = 256;
        let g = MemGraph::from_edges(n, &edges, true);
        let csr = Csr::from_edges(n, &edges, true);
        let sources: Vec<VertexId> = vec![0, 3, 17, 42, 99];
        let (ecc, _) = ms_bfs(&g, &sources, &EngineConfig { workers: 4, ..Default::default() });
        for (lane, &s) in sources.iter().enumerate() {
            assert_eq!(ecc[lane], oracle::eccentricity(&csr, s), "lane {lane} src {s}");
        }
    }

    #[test]
    fn ms_bfs_visited_matches_reachability() {
        let edges = vec![(0u32, 1u32), (1, 2), (3, 4)]; // two components
        let g = MemGraph::from_edges(5, &edges, true);
        let prog = MsBfs::new(5, &[0, 3]);
        Engine::run(&prog, &g, &[0, 3], &EngineConfig::default());
        let masks = prog.visited_masks();
        assert_eq!(masks[0], 0b01);
        assert_eq!(masks[1], 0b01);
        assert_eq!(masks[2], 0b01);
        assert_eq!(masks[3], 0b10);
        assert_eq!(masks[4], 0b10);
    }

    #[test]
    fn ms_bfs_64_lanes() {
        let edges = gen::cycle(128);
        let g = MemGraph::from_edges(128, &edges, true);
        let sources: Vec<VertexId> = (0..64).map(|i| i * 2).collect();
        let (ecc, _) = ms_bfs(&g, &sources, &EngineConfig::default());
        // directed cycle of 128: every vertex has eccentricity 127
        assert!(ecc.iter().all(|&e| e == 127), "{ecc:?}");
    }

    #[test]
    fn ms_bfs_shares_io_across_lanes() {
        // many sources in one multi-source run must fetch far fewer edge
        // lists than the same sources run uni-source sequentially
        let edges = gen::rmat(9, 4000, 6);
        let n = 512;
        let sources: Vec<VertexId> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let cfg = EngineConfig { workers: 4, ..Default::default() };

        let g_multi = MemGraph::from_edges(n, &edges, true);
        let (_, multi) = ms_bfs(&g_multi, &sources, &cfg);

        let g_uni = MemGraph::from_edges(n, &edges, true);
        let mut uni_reqs = 0;
        for &s in &sources {
            let (_, r) = bfs(&g_uni, s, &cfg);
            uni_reqs += r.io.read_requests;
        }
        assert!(
            multi.io.read_requests < uni_reqs,
            "multi {} < uni {}",
            multi.io.read_requests,
            uni_reqs
        );
    }
}
