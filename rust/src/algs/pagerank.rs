//! PageRank — §4.1, the *limit superfluous reads* principle.
//!
//! Two implementations of the same fixpoint
//! `R = (1-α)/n + α · Mᵀ R` (no dangling redistribution, the convention
//! shared with [`super::oracle::pagerank`]):
//!
//! * **PR-pull** ([`pagerank_pull`]) — the Pregel/Turi baseline:
//!   synchronous gather/scatter. Every superstep, every non-globally-
//!   converged vertex gathers the shares its in-neighbors sent last
//!   superstep, recomputes, and re-scatters. A vertex *cannot* drop out
//!   while its in-neighbors keep sending — hubs converge slowly, so they
//!   keep re-activating nearly the whole graph, and each activation
//!   re-fetches an edge list whose neighborhood has long converged. That
//!   is the superfluous I/O (and activation, and messaging) the paper
//!   calls out.
//!
//! * **PR-push** ([`pagerank_push`]) — residual push: a vertex drains its
//!   accumulated residual into its rank and pushes `α·r/outdeg` to its
//!   out-neighbors *only when the residual exceeds the threshold*. Only
//!   vertices with meaningful residual are ever activated — the minimal
//!   activation set, with a matching reduction in edge-list fetches.
//!
//! Figure 2 compares runtime, read bytes, read requests and thread waits
//! between the two (`cargo bench --bench fig2_pagerank`).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::engine::{
    CheckpointImage, CheckpointWriter, Combiner, Engine, EngineConfig, EndCtx, RunReport,
    VertexProgram, WorkerCtx,
};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::atomic_f64::{atomic_f64_vec, AtomicF64};
use crate::util::SharedVec;
use crate::VertexId;

/// Result of a PageRank run.
pub struct PageRankResult {
    /// Final rank per vertex.
    pub rank: Vec<f64>,
    /// Engine + I/O report.
    pub report: RunReport,
}

// ---------------------------------------------------------------- push --

struct PrPush {
    alpha: f64,
    threshold: f64,
    // single-writer-per-phase access only (run_on_message runs on the
    // owner, run_on_vertex on the chunk claimant, barrier-separated),
    // so plain SharedVec slots — no atomics on the hot path
    rank: SharedVec<f64>,
    residual: SharedVec<f64>,
    /// This round's outgoing share per vertex, stashed by
    /// `run_on_vertex` so pull rounds can synthesize the identical
    /// message per out-edge (written in B1, read in B2 — the
    /// stable-in-phase discipline [`VertexProgram::pull_message`]
    /// requires).
    share: SharedVec<f64>,
}

impl VertexProgram for PrPush {
    type Msg = f64; // residual share

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out // the whole point: never touch in-lists
    }

    // rank mass is additive: shares to the same destination fold in the
    // dense combiner lanes (O(n) message memory, one delivery per dst)
    fn combiner(&self) -> Option<Combiner<f64>> {
        Some(Combiner { identity: || 0.0, combine: |a, b| *a += *b })
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, f64>, v: VertexId, edges: &VertexEdges) {
        let r = std::mem::take(self.residual.get_mut(v as usize));
        // the share is computed from the index degree, not the fetched
        // list, so a pull round's edge-less B1 pass stashes exactly what
        // a push round would multicast
        let outdeg = ctx.out_deg(v) as usize;
        let share = if r == 0.0 || outdeg == 0 {
            0.0 // dangling: mass retained, not redistributed
        } else {
            self.alpha * r / outdeg as f64
        };
        *self.share.get_mut(v as usize) = share;
        if r != 0.0 {
            *self.rank.get_mut(v as usize) += r;
        }
        if share != 0.0 && !edges.out_neighbors.is_empty() {
            ctx.multicast(&edges.out_neighbors, share);
        }
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, f64>, v: VertexId, share: &f64) {
        let slot = self.residual.get_mut(v as usize);
        *slot += *share;
        if *slot > self.threshold {
            // activate into this round's vertex phase: the residual is
            // drained promptly while its cache pages are likely warm
            ctx.activate(v);
        }
    }

    fn supports_pull(&self) -> bool {
        true
    }

    fn pull_message(&self, src: VertexId, _dst: VertexId) -> Option<f64> {
        let share = *self.share.get(src as usize);
        (share != 0.0).then_some(share)
    }

    // the program's whole O(n) state is these three arrays; together
    // with the engine's frontier + folded-message snapshot they make a
    // resumed run bit-identical to an uninterrupted one (at a fixed
    // worker count — f64 folding order is worker-dependent)
    fn checkpointable(&self) -> bool {
        true
    }

    fn checkpoint_save(&self, w: &mut CheckpointWriter) {
        w.put_f64("rank", &self.rank);
        w.put_f64("residual", &self.residual);
        w.put_f64("share", &self.share);
    }

    fn checkpoint_restore(&self, img: &CheckpointImage) -> crate::Result<()> {
        img.restore_f64("rank", &self.rank)?;
        img.restore_f64("residual", &self.residual)?;
        img.restore_f64("share", &self.share)
    }
}

/// Run PR-push. `threshold` bounds the per-vertex residual left
/// unpropagated (1e-9 gives ~1e-6 rank accuracy on 100k-vertex graphs).
pub fn pagerank_push(
    source: &dyn EdgeSource,
    alpha: f64,
    threshold: f64,
    cfg: &EngineConfig,
) -> PageRankResult {
    let n = source.index().num_vertices();
    let prog = PrPush {
        alpha,
        threshold,
        rank: SharedVec::new(n, 0.0),
        residual: SharedVec::new(n, (1.0 - alpha) / n as f64),
        share: SharedVec::new(n, 0.0),
    };
    let all: Vec<VertexId> = (0..n as VertexId).collect();
    let report = Engine::run(&prog, source, &all, cfg);
    PageRankResult { rank: prog.rank.to_vec(), report }
}

// ---------------------------------------------------------------- pull --

struct PrPull {
    alpha: f64,
    threshold: f64,
    max_iters: usize,
    /// Current rank (claimant-written in run_on_vertex).
    rank: Vec<AtomicF64>,
    /// Gathered contributions for the next compute (message-accumulated
    /// on the owner worker).
    acc: SharedVec<f64>,
    iters: AtomicUsize,
}

impl VertexProgram for PrPull {
    type Msg = f64; // rank share from an in-neighbor (previous superstep)

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out
    }

    fn combiner(&self) -> Option<Combiner<f64>> {
        Some(Combiner { identity: || 0.0, combine: |a, b| *a += *b })
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, f64>, v: VertexId, edges: &VertexEdges) {
        let n = ctx.num_vertices() as f64;
        // gather: everything in-neighbors scattered last superstep
        let sum = std::mem::take(self.acc.get_mut(v as usize));
        let old = self.rank[v as usize].load();
        let new = if ctx.round() == 0 {
            old // superstep 0: nothing gathered yet, scatter the initial rank
        } else {
            (1.0 - self.alpha) / n + self.alpha * sum
        };
        self.rank[v as usize].store(new);
        ctx.reduce_max(0, (new - old).abs());
        // scatter to out-neighbors and stay active: in the Pregel model a
        // vertex cannot deactivate while its in-neighbors keep sending —
        // hubs keep almost the whole graph active until *global*
        // convergence (the superfluous work PR-push eliminates)
        if !edges.out_neighbors.is_empty() {
            ctx.multicast(&edges.out_neighbors, new / edges.out_neighbors.len() as f64);
        }
        ctx.activate(v);
    }

    fn run_on_message(&self, _ctx: &mut WorkerCtx<'_, f64>, v: VertexId, share: &f64) {
        *self.acc.get_mut(v as usize) += *share;
    }

    fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
        let max_delta = ctx.reduction_max(0);
        let it = self.iters.fetch_add(1, Ordering::Relaxed) + 1;
        if (ctx.round() > 0 && max_delta < self.threshold) || it >= self.max_iters {
            ctx.stop();
        }
    }
}

/// Run PR-pull — the Pregel/Turi-style baseline of Fig. 2: synchronous
/// gather/scatter with every vertex active until *global* convergence.
pub fn pagerank_pull(
    source: &dyn EdgeSource,
    alpha: f64,
    threshold: f64,
    max_iters: usize,
    cfg: &EngineConfig,
) -> PageRankResult {
    let n = source.index().num_vertices();
    let prog = PrPull {
        alpha,
        threshold,
        max_iters,
        rank: atomic_f64_vec(n, 1.0 / n as f64),
        acc: SharedVec::new(n, 0.0),
        iters: AtomicUsize::new(0),
    };
    let all: Vec<VertexId> = (0..n as VertexId).collect();
    let report = Engine::run(&prog, source, &all, cfg);
    PageRankResult { rank: prog.rank.iter().map(|a| a.load()).collect(), report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    fn l1_err(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    fn check_both_match_oracle(n: usize, edges: &[(VertexId, VertexId)]) {
        let g = MemGraph::from_edges(n, edges, true);
        let csr = Csr::from_edges(n, edges, true);
        let want = oracle::pagerank(&csr, 0.85, 200);
        let cfg = EngineConfig { workers: 4, ..Default::default() };
        let push = pagerank_push(&g, 0.85, 1e-12, &cfg);
        let pull = pagerank_pull(&g, 0.85, 1e-12, 500, &cfg);
        assert!(
            l1_err(&push.rank, &want) < 1e-6,
            "push L1 err {}",
            l1_err(&push.rank, &want)
        );
        assert!(
            l1_err(&pull.rank, &want) < 1e-6,
            "pull L1 err {}",
            l1_err(&pull.rank, &want)
        );
    }

    #[test]
    fn matches_oracle_on_cycle() {
        check_both_match_oracle(20, &gen::cycle(20));
    }

    #[test]
    fn matches_oracle_on_rmat() {
        let edges = gen::rmat(8, 2000, 3);
        check_both_match_oracle(256, &edges);
    }

    #[test]
    fn matches_oracle_with_dangling() {
        // path: last vertex dangling
        check_both_match_oracle(10, &gen::path(10));
    }

    #[test]
    fn push_reads_less_than_pull() {
        // the principle itself: PR-push must demand fewer edge bytes —
        // pull fetches BOTH lists per activation and keeps re-gathering
        // neighborhoods whose ranks have converged
        let edges = gen::rmat(10, 10_000, 9);
        let n = 1024;
        let thr = 1e-3 / n as f64; // a realistic convergence threshold
        let g = MemGraph::from_edges(n, &edges, true);
        let cfg = EngineConfig { workers: 4, ..Default::default() };
        let push = pagerank_push(&g, 0.85, thr, &cfg);
        let g2 = MemGraph::from_edges(n, &edges, true);
        let pull = pagerank_pull(&g2, 0.85, thr, 500, &cfg);
        assert!(
            push.report.io.logical_bytes < pull.report.io.logical_bytes,
            "push {} bytes vs pull {} bytes",
            push.report.io.logical_bytes,
            pull.report.io.logical_bytes
        );
        assert!(l1_err(&push.rank, &pull.rank) < 1e-2);
    }

    #[test]
    fn rank_mass_bounded() {
        let edges = gen::rmat(8, 1500, 5);
        let g = MemGraph::from_edges(256, &edges, true);
        let r = pagerank_push(&g, 0.85, 1e-12, &EngineConfig::default());
        let total: f64 = r.rank.iter().sum();
        assert!(total > 0.0 && total <= 1.0 + 1e-9, "total {total}");
        assert!(r.rank.iter().all(|&x| x >= 0.0));
    }
}
