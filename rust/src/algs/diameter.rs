//! Graph diameter estimation — §4.3: *decouple algorithm development from
//! framework constructs*.
//!
//! The estimator BFS-sweeps from *pseudo-peripheral* vertices: start from
//! a hub, find the farthest frontier, then measure eccentricities from a
//! set of those extremal vertices. The paper's point is the second phase:
//!
//! * **uni-source** — one BFS per candidate, sequentially. Each sweep
//!   re-fetches the same edge lists, frontiers are narrow, and every BFS
//!   level pays a global barrier: heavily I/O- and barrier-bound.
//! * **multi-source** — all candidates sweep concurrently in one run
//!   (bit lanes, [`crate::algs::bfs::MsBfs`]): each fetched edge list
//!   serves every lane whose frontier touches it, raising page-cache
//!   hits and cutting barrier count (Figs. 4–5).

use crate::algs::bfs::{bfs, ms_bfs};
use crate::algs::degree::top_k_by_degree;
use crate::engine::{EngineConfig, RunReport};
use crate::graph::source::EdgeSource;
use crate::VertexId;

/// Which sweep strategy to use for the eccentricity phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiameterVariant {
    /// One BFS per candidate, run sequentially.
    UniSource,
    /// All candidates in one multi-source BFS.
    MultiSource,
}

/// Result of a diameter estimation.
pub struct DiameterResult {
    /// Estimated diameter (max observed eccentricity).
    pub diameter: i64,
    /// The candidate sources actually swept.
    pub sources: Vec<VertexId>,
    /// Aggregate report across all engine runs (seed phase + sweeps).
    pub report: RunReport,
}

/// Estimate the diameter with `num_sweeps` pseudo-peripheral sweeps
/// (≤ 64).
pub fn estimate_diameter(
    source: &dyn EdgeSource,
    num_sweeps: usize,
    variant: DiameterVariant,
    cfg: &EngineConfig,
) -> DiameterResult {
    assert!((1..=64).contains(&num_sweeps));
    let mut reports = Vec::new();

    // Phase 1 (shared by both variants): BFS from the highest-degree hub
    // to find pseudo-peripheral candidates — vertices at maximal level.
    let hub = top_k_by_degree(source.index(), 1)[0];
    let (levels, r0) = bfs(source, hub, cfg);
    reports.push(r0);
    let max_level = levels.iter().copied().max().unwrap_or(0);
    let mut candidates: Vec<VertexId> = Vec::new();
    // prefer the deepest vertices, then progressively closer ones
    let mut want_level = max_level;
    while candidates.len() < num_sweeps && want_level > 0 {
        for (v, &l) in levels.iter().enumerate() {
            if l == want_level && candidates.len() < num_sweeps {
                candidates.push(v as VertexId);
            }
        }
        want_level -= 1;
    }
    if candidates.is_empty() {
        candidates.push(hub);
    }

    // Phase 2: eccentricity sweeps.
    let mut diameter = max_level;
    match variant {
        DiameterVariant::UniSource => {
            for &s in &candidates {
                let (lv, r) = bfs(source, s, cfg);
                reports.push(r);
                diameter = diameter.max(lv.iter().copied().max().unwrap_or(0));
            }
        }
        DiameterVariant::MultiSource => {
            let (ecc, r) = ms_bfs(source, &candidates, cfg);
            reports.push(r);
            diameter = diameter.max(ecc.into_iter().max().unwrap_or(0));
        }
    }

    DiameterResult { diameter, sources: candidates, report: RunReport::merged(&reports) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    #[test]
    fn grid_diameter_exact() {
        // 8x8 grid: true diameter 14; extremal sweeps find it
        let g = MemGraph::from_edges(64, &gen::grid_2d(8, 8), false);
        for variant in [DiameterVariant::UniSource, DiameterVariant::MultiSource] {
            let r = estimate_diameter(&g, 4, variant, &EngineConfig::default());
            assert_eq!(r.diameter, 14, "{variant:?}");
        }
    }

    #[test]
    fn path_diameter() {
        let g = MemGraph::from_edges(30, &gen::path(30), false);
        let r = estimate_diameter(&g, 2, DiameterVariant::MultiSource, &EngineConfig::default());
        assert_eq!(r.diameter, 29);
    }

    #[test]
    fn variants_agree_and_multi_does_less_io() {
        let edges = gen::rmat(9, 3000, 31);
        let g1 = MemGraph::from_edges(512, &edges, true);
        let uni = estimate_diameter(&g1, 8, DiameterVariant::UniSource, &EngineConfig::default());
        let g2 = MemGraph::from_edges(512, &edges, true);
        let multi =
            estimate_diameter(&g2, 8, DiameterVariant::MultiSource, &EngineConfig::default());
        // same candidate set => same estimate
        assert_eq!(uni.diameter, multi.diameter);
        assert_eq!(uni.sources, multi.sources);
        assert!(
            multi.report.io.read_requests < uni.report.io.read_requests,
            "multi {} < uni {}",
            multi.report.io.read_requests,
            uni.report.io.read_requests
        );
        assert!(multi.report.rounds < uni.report.rounds);
    }
}
