//! The algorithm library: the paper's six applications (each in its
//! unoptimized and Graphyti-optimized variants) plus library extras.
//!
//! | module | paper § | principle demonstrated |
//! |--------|---------|------------------------|
//! | [`pagerank`] | 4.1 | limit superfluous reads (push vs pull) |
//! | [`coreness`] | 4.2 | minimize messaging; prune computation |
//! | [`diameter`] | 4.3 | decouple algorithm from framework constructs |
//! | [`bc`] | 4.4 | asynchronous applications; functional constructs |
//! | [`triangles`] | 4.5 | optimize in-memory operations |
//! | [`louvain`] | 4.6 | avoid graph structure modification |
//!
//! Extras: [`bfs`] (uni- and multi-source), [`wcc`], [`sssp`],
//! [`degree`], [`scan_stat`] (Priebe's scan-1 locality statistic).
//! [`oracle`] holds single-threaded in-memory references used by tests
//! throughout.

pub mod bc;
pub mod bfs;
pub mod coreness;
pub mod degree;
pub mod diameter;
pub mod louvain;
pub mod oracle;
pub mod pagerank;
pub mod scan_stat;
pub mod sssp;
pub mod triangles;
pub mod wcc;
