//! Betweenness centrality (Brandes) — §4.4: *develop asynchronous
//! applications* and *utilize functional constructs*.
//!
//! Per source, Brandes has three phases: BFS (distances + shortest-path
//! counts σ), backward propagation (dependency δ, by descending BFS
//! level), and accumulation into BC. Three variants:
//!
//! * [`BcVariant::UniSource`] — one engine run per source: the baseline
//!   whose narrow frontiers and per-phase barriers the paper criticizes.
//! * [`BcVariant::MultiSourceSync`] — up to 32 sources as bit lanes in
//!   one run, but *phase-synchronous*: no lane starts backward
//!   propagation until every lane finished BFS. Lanes with shallow BFS
//!   trees idle while deep lanes finish — the cost of phase synchrony.
//! * [`BcVariant::MultiSourceAsync`] — the Graphyti design: each lane
//!   flows into its own backward phase the moment its BFS quiesces, so
//!   forward messages of one lane and backward messages of another share
//!   rounds (and fetched pages). Activation metadata carries the lane
//!   *and* phase, exactly as the paper describes.
//!
//! Lockstep correctness: the engine delivers all round-*r−1* messages in
//! round *r*'s message phase *before* the vertex phase, so σ at level *L*
//! is complete before level-*L* vertices forward it, and δ at level *L−1*
//! is complete before those vertices propagate it upward.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use crate::engine::{Engine, EngineConfig, EndCtx, RunReport, VertexProgram, WorkerCtx};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::SharedVec;
use crate::VertexId;

/// Execution strategy (what Fig. 6 compares).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BcVariant {
    /// One engine run per source.
    UniSource,
    /// One run, lanes phase-locked (BFS for all, then BP for all).
    MultiSourceSync,
    /// One run, per-lane phases interleave freely.
    MultiSourceAsync,
}

#[derive(Debug, Clone, Copy)]
enum LaneState {
    Bfs,
    /// BFS finished (depth recorded); waiting for the global BP gate
    /// (sync mode only).
    WaitBp { max: i32 },
    /// Backward propagation: `cur` is the next level to schedule.
    Bp { cur: i32 },
    Done,
}

/// Messages carry lane + phase metadata (§4.4).
///
/// Path counting is *not* commutative-associative across message kinds
/// (a `Fwd` σ-sum and a `Bwd` δ-contribution for different lanes and
/// distances cannot be folded into one value), so BC declares no
/// [`crate::engine::Combiner`] and rides the recycled SPSC queue lanes
/// — the transport whose multicast entries share one payload per
/// destination worker.
#[derive(Clone)]
enum BcMsg {
    /// Forward: shortest-path count contribution from a level-(d-1)
    /// predecessor.
    Fwd { lane: u8, sigma: f64 },
    /// Backward: dependency contribution; receivers at `dist - 1` apply
    /// `delta += sigma_recv * val` where `val = (1 + delta_v) / sigma_v`.
    Bwd { lane: u8, dist: i32, val: f64 },
}

struct Bc {
    lanes: usize,
    sources: Vec<VertexId>,
    sync: bool,
    /// Directed image? (undirected images keep all neighbors in `out`)
    directed: bool,
    /// dist/sigma/delta are (n × lanes) flattened; single-writer-per-
    /// phase slots (owner in message phase, claimant in vertex phase).
    dist: SharedVec<i32>,
    sigma: SharedVec<f64>,
    delta: SharedVec<f64>,
    /// Lanes whose BFS frontier reached the vertex this round.
    gained: SharedVec<u32>,
    /// Lanes for which the vertex must emit backward messages this round.
    bp_lanes: SharedVec<u32>,
    /// Lanes with BFS progress this round.
    progress: AtomicU32,
    state: Mutex<Vec<LaneState>>,
    /// Accumulated centrality (hook-updated, single-threaded).
    bc: SharedVec<f64>,
}

impl Bc {
    #[inline]
    fn at(&self, v: VertexId, lane: usize) -> usize {
        v as usize * self.lanes + lane
    }
}

impl VertexProgram for Bc {
    type Msg = BcMsg;

    fn edge_request(&self, v: VertexId) -> EdgeRequest {
        // metadata decides which lists this activation needs:
        // forward frontier -> out-edges, backward wave -> in-edges.
        let fwd = *self.gained.get(v as usize) != 0;
        let bwd = *self.bp_lanes.get(v as usize) != 0;
        if !self.directed {
            // undirected images hold the full neighbor list in `out`
            return if fwd || bwd { EdgeRequest::Out } else { EdgeRequest::None };
        }
        match (fwd, bwd) {
            (true, true) => EdgeRequest::Both,
            (true, false) => EdgeRequest::Out,
            (false, true) => EdgeRequest::In,
            (false, false) => EdgeRequest::None,
        }
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, BcMsg>, v: VertexId, edges: &VertexEdges) {
        let fwd = std::mem::take(self.gained.get_mut(v as usize));
        if fwd != 0 {
            for lane in 0..self.lanes {
                if fwd & (1 << lane) != 0 {
                    let sigma = *self.sigma.get(self.at(v, lane));
                    ctx.multicast(
                        &edges.out_neighbors,
                        BcMsg::Fwd { lane: lane as u8, sigma },
                    );
                }
            }
        }
        let bwd = std::mem::take(self.bp_lanes.get_mut(v as usize));
        if bwd != 0 {
            for lane in 0..self.lanes {
                if bwd & (1 << lane) != 0 {
                    let i = self.at(v, lane);
                    let sigma = *self.sigma.get(i);
                    if sigma == 0.0 {
                        continue;
                    }
                    let val = (1.0 + *self.delta.get(i)) / sigma;
                    let preds: &[VertexId] = if self.directed {
                        &edges.in_neighbors
                    } else {
                        &edges.out_neighbors
                    };
                    ctx.multicast(
                        preds,
                        BcMsg::Bwd { lane: lane as u8, dist: *self.dist.get(i), val },
                    );
                }
            }
        }
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, BcMsg>, v: VertexId, msg: &BcMsg) {
        match *msg {
            BcMsg::Fwd { lane, sigma } => {
                let i = self.at(v, lane as usize);
                let d = self.dist.get_mut(i);
                let round = ctx.round() as i32;
                if *d < 0 {
                    // first touch: this is a shortest path of length `round`
                    *d = round;
                    *self.sigma.get_mut(i) += sigma;
                    *self.gained.get_mut(v as usize) |= 1 << lane;
                    self.progress.fetch_or(1 << lane, Ordering::Relaxed);
                    ctx.activate(v); // same round: lockstep level = round
                } else if *d == round {
                    // another shortest path discovered in the same level
                    *self.sigma.get_mut(i) += sigma;
                } // else: longer path, ignore
            }
            BcMsg::Bwd { lane, dist, val } => {
                let i = self.at(v, lane as usize);
                if *self.dist.get(i) == dist - 1 {
                    // v is a predecessor on a shortest path
                    *self.delta.get_mut(i) += *self.sigma.get(i) * val;
                }
                // activation comes from the scheduler (iteration-end hook)
            }
        }
    }

    fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
        let progress = self.progress.swap(0, Ordering::Relaxed);
        let round = ctx.round() as i32;
        let n = ctx.num_vertices();
        let mut state = self.state.lock().unwrap();

        // 1. BFS completion detection. A lane is done when its frontier
        //    produced no new vertices this round — or when the whole
        //    engine is quiescent (a frontier can die without emitting
        //    messages, e.g. sink vertices; without this the engine would
        //    stop before the next hook could notice).
        for lane in 0..self.lanes {
            if let LaneState::Bfs = state[lane] {
                if progress & (1 << lane) == 0 && ctx.round() >= 1 {
                    // deepest level = last round with progress = round - 1
                    state[lane] = LaneState::WaitBp { max: round - 1 };
                } else if ctx.quiescent() {
                    // progressed this round but nothing is in flight:
                    // level `round` was the last one
                    state[lane] = LaneState::WaitBp { max: round };
                }
            }
        }

        // 2. BP gate: async releases each lane immediately; sync waits for
        //    every lane to leave Bfs.
        let all_bfs_done = state.iter().all(|s| !matches!(s, LaneState::Bfs));
        for lane in 0..self.lanes {
            if let LaneState::WaitBp { max } = state[lane] {
                if !self.sync || all_bfs_done {
                    state[lane] = LaneState::Bp { cur: max };
                }
            }
        }

        // 3. BP scheduling: activate the next level down for each lane.
        for lane in 0..self.lanes {
            if let LaneState::Bp { cur } = state[lane] {
                if cur >= 1 {
                    for v in 0..n {
                        if *self.dist.get(v * self.lanes + lane) == cur {
                            *self.bp_lanes.get_mut(v) |= 1 << lane;
                            ctx.activate(v as VertexId);
                        }
                    }
                    state[lane] = LaneState::Bp { cur: cur - 1 };
                } else {
                    // all levels scheduled and delivered: accumulate
                    let s = self.sources[lane];
                    for v in 0..n {
                        if v as VertexId != s {
                            let d = *self.delta.get(v * self.lanes + lane);
                            if d != 0.0 {
                                *self.bc.get_mut(v) += d;
                            }
                        }
                    }
                    state[lane] = LaneState::Done;
                }
            }
        }
    }
}

/// Result of a betweenness run.
pub struct BcResult {
    /// Centrality per vertex (unnormalized, directed-path convention —
    /// identical to [`crate::algs::oracle::betweenness`]).
    pub bc: Vec<f64>,
    /// Aggregate report.
    pub report: RunReport,
}

fn run_batch(
    source: &dyn EdgeSource,
    sources: &[VertexId],
    sync: bool,
    cfg: &EngineConfig,
) -> (Vec<f64>, RunReport) {
    let n = source.index().num_vertices();
    let lanes = sources.len();
    assert!((1..=32).contains(&lanes), "1..=32 sources per batch");
    let prog = Bc {
        lanes,
        sources: sources.to_vec(),
        sync,
        directed: source.index().directed(),
        dist: SharedVec::new(n * lanes, -1),
        sigma: SharedVec::new(n * lanes, 0.0),
        delta: SharedVec::new(n * lanes, 0.0),
        gained: SharedVec::new(n, 0u32),
        bp_lanes: SharedVec::new(n, 0u32),
        progress: AtomicU32::new(0),
        state: Mutex::new(vec![LaneState::Bfs; lanes]),
        bc: SharedVec::new(n, 0.0),
    };
    for (lane, &s) in sources.iter().enumerate() {
        prog.dist.set(s as usize * lanes + lane, 0);
        prog.sigma.set(s as usize * lanes + lane, 1.0);
        *prog.gained.get_mut(s as usize) |= 1 << lane;
    }
    let report = Engine::run(&prog, source, sources, cfg);
    (prog.bc.to_vec(), report)
}

/// Compute betweenness centrality over `sources` with the given variant.
pub fn betweenness(
    source: &dyn EdgeSource,
    sources: &[VertexId],
    variant: BcVariant,
    cfg: &EngineConfig,
) -> BcResult {
    match variant {
        BcVariant::UniSource => {
            let n = source.index().num_vertices();
            let mut bc = vec![0.0f64; n];
            let mut reports = Vec::new();
            for &s in sources {
                let (b, r) = run_batch(source, &[s], true, cfg);
                for (acc, x) in bc.iter_mut().zip(b) {
                    *acc += x;
                }
                reports.push(r);
            }
            BcResult { bc, report: RunReport::merged(&reports) }
        }
        BcVariant::MultiSourceSync | BcVariant::MultiSourceAsync => {
            let sync = variant == BcVariant::MultiSourceSync;
            let n = source.index().num_vertices();
            let mut bc = vec![0.0f64; n];
            let mut reports = Vec::new();
            for chunk in sources.chunks(32) {
                let (b, r) = run_batch(source, chunk, sync, cfg);
                for (acc, x) in bc.iter_mut().zip(b) {
                    *acc += x;
                }
                reports.push(r);
            }
            BcResult { bc, report: RunReport::merged(&reports) }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    const VARIANTS: [BcVariant; 3] =
        [BcVariant::UniSource, BcVariant::MultiSourceSync, BcVariant::MultiSourceAsync];

    fn assert_close(got: &[f64], want: &[f64], tag: &str) {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!((g - w).abs() < 1e-9, "{tag}: bc[{i}] got {g} want {w}");
        }
    }

    fn check_all(n: usize, edges: &[(VertexId, VertexId)], directed: bool, sources: &[VertexId]) {
        let csr = Csr::from_edges(n, edges, directed);
        let want = oracle::betweenness(&csr, sources);
        for variant in VARIANTS {
            let g = MemGraph::from_edges(n, edges, directed);
            let got =
                betweenness(&g, sources, variant, &EngineConfig { workers: 4, ..Default::default() });
            assert_close(&got.bc, &want, &format!("{variant:?}"));
        }
    }

    #[test]
    fn path_graph_exact() {
        let all: Vec<VertexId> = (0..6).collect();
        check_all(6, &gen::path(6), false, &all);
    }

    #[test]
    fn diamond_multiple_shortest_paths() {
        // 0 -> 1,2 -> 3: two shortest paths through the middle
        check_all(4, &[(0, 1), (0, 2), (1, 3), (2, 3)], true, &[0, 1, 2, 3]);
    }

    #[test]
    fn grid_undirected() {
        let sources: Vec<VertexId> = vec![0, 5, 12, 15];
        check_all(16, &gen::grid_2d(4, 4), false, &sources);
    }

    #[test]
    fn rmat_directed() {
        let edges = gen::rmat(7, 800, 55);
        let sources: Vec<VertexId> = vec![0, 1, 2, 3, 17, 31, 64, 100];
        check_all(128, &edges, true, &sources);
    }

    #[test]
    fn disconnected_sources() {
        // source in a tiny component: must not contaminate the big one
        check_all(6, &[(0, 1), (1, 2), (4, 5)], true, &[0, 4]);
    }

    #[test]
    fn async_uses_fewer_rounds_than_sync_than_uni() {
        let edges = gen::rmat(9, 4000, 91);
        let sources: Vec<VertexId> = (0..16).collect();
        let cfg = EngineConfig { workers: 4, ..Default::default() };
        let g1 = MemGraph::from_edges(512, &edges, true);
        let uni = betweenness(&g1, &sources, BcVariant::UniSource, &cfg);
        let g2 = MemGraph::from_edges(512, &edges, true);
        let sync = betweenness(&g2, &sources, BcVariant::MultiSourceSync, &cfg);
        let g3 = MemGraph::from_edges(512, &edges, true);
        let asyn = betweenness(&g3, &sources, BcVariant::MultiSourceAsync, &cfg);
        assert_close(&uni.bc, &sync.bc, "uni-vs-sync");
        assert_close(&uni.bc, &asyn.bc, "uni-vs-async");
        // multi-source shares rounds/barriers across lanes; async removes
        // the BP gate and shaves further rounds (the paper's async win is
        // parallel efficiency, not raw request count)
        assert!(sync.report.rounds < uni.report.rounds, "sync {} < uni {}", sync.report.rounds, uni.report.rounds);
        assert!(asyn.report.rounds <= sync.report.rounds, "async {} <= sync {}", asyn.report.rounds, sync.report.rounds);
        assert!(
            asyn.report.io.read_requests < uni.report.io.read_requests,
            "async {} < uni {} read reqs",
            asyn.report.io.read_requests,
            uni.report.io.read_requests
        );
    }
}
