//! Single-source shortest paths (label-correcting relaxation) — library
//! extra. Weights are the deterministic synthetic function
//! [`crate::algs::oracle::edge_weight`] so the graph image stores nothing
//! extra (the image format is unweighted; see DESIGN.md).

use crate::algs::oracle::edge_weight;
use crate::engine::{Combiner, Engine, EngineConfig, RunReport, VertexProgram, WorkerCtx};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::SharedVec;
use crate::VertexId;

struct Sssp {
    dist: SharedVec<u64>,
}

impl VertexProgram for Sssp {
    type Msg = u64; // proposed distance

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out
    }

    // label correction keeps only the best proposal: min-combinable
    fn combiner(&self) -> Option<Combiner<u64>> {
        Some(Combiner { identity: || u64::MAX, combine: |a, b| *a = (*a).min(*b) })
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, u64>, v: VertexId, edges: &VertexEdges) {
        let d = *self.dist.get(v as usize);
        // per-edge weights differ, so relaxations are point-to-point
        for &u in &edges.out_neighbors {
            ctx.send(u, d + edge_weight(v, u));
        }
    }

    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, u64>, v: VertexId, nd: &u64) {
        let cur = self.dist.get_mut(v as usize);
        if *nd < *cur {
            *cur = *nd;
            ctx.activate(v); // label-correcting: re-relax promptly
        }
    }

    fn supports_pull(&self) -> bool {
        true
    }

    fn pull_message(&self, src: VertexId, dst: VertexId) -> Option<u64> {
        // the weight is a pure function of the edge endpoints, so the
        // pull side reconstructs exactly the proposal push would send;
        // dist[src] is phase-A-written and stable through phase B
        Some(*self.dist.get(src as usize) + edge_weight(src, dst))
    }
}

/// Shortest synthetic-weight distances from `src` (u64::MAX unreachable).
pub fn sssp(source: &dyn EdgeSource, src: VertexId, cfg: &EngineConfig) -> (Vec<u64>, RunReport) {
    let n = source.index().num_vertices();
    let prog = Sssp { dist: SharedVec::new(n, u64::MAX) };
    prog.dist.set(src as usize, 0);
    let report = Engine::run(&prog, source, &[src], cfg);
    (prog.dist.into_vec(), report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    #[test]
    fn matches_dijkstra_on_rmat() {
        let edges = gen::rmat(8, 2000, 21);
        let g = MemGraph::from_edges(256, &edges, true);
        let csr = Csr::from_edges(256, &edges, true);
        let (got, _) = sssp(&g, 0, &EngineConfig { workers: 4, ..Default::default() });
        assert_eq!(got, oracle::sssp(&csr, 0));
    }

    #[test]
    fn matches_dijkstra_on_grid() {
        let edges = gen::grid_2d(8, 8);
        let g = MemGraph::from_edges(64, &edges, false);
        let csr = Csr::from_edges(64, &edges, false);
        let (got, _) = sssp(&g, 27, &EngineConfig::default());
        assert_eq!(got, oracle::sssp(&csr, 27));
    }

    #[test]
    fn unreachable_is_max() {
        let g = MemGraph::from_edges(3, &[(0, 1)], true);
        let (got, _) = sssp(&g, 0, &EngineConfig::default());
        assert_eq!(got[2], u64::MAX);
    }
}
