//! Single-threaded in-memory reference implementations ("oracles").
//!
//! Textbook algorithms over [`Csr`] with no engine, no SEM, no
//! parallelism — the ground truth every vertex-centric implementation is
//! tested against. Deliberately simple; performance does not matter here.

use std::collections::VecDeque;

use crate::graph::csr::Csr;
use crate::VertexId;

/// Damped PageRank by dense power iteration (no dangling redistribution —
/// the same convention as both SEM variants; see `algs::pagerank`).
pub fn pagerank(g: &Csr, alpha: f64, iters: usize) -> Vec<f64> {
    let n = g.num_vertices();
    let base = (1.0 - alpha) / n as f64;
    let mut rank = vec![1.0 / n as f64; n];
    let mut next = vec![0.0f64; n];
    for _ in 0..iters {
        next.iter_mut().for_each(|x| *x = base);
        for u in 0..n as VertexId {
            let outs = g.out(u);
            if outs.is_empty() {
                continue;
            }
            let share = alpha * rank[u as usize] / outs.len() as f64;
            for &v in outs {
                next[v as usize] += share;
            }
        }
        std::mem::swap(&mut rank, &mut next);
    }
    rank
}

/// BFS hop levels from `src` following out-edges (-1 = unreachable).
pub fn bfs_levels(g: &Csr, src: VertexId) -> Vec<i64> {
    let n = g.num_vertices();
    let mut level = vec![-1i64; n];
    level[src as usize] = 0;
    let mut q = VecDeque::from([src]);
    while let Some(u) = q.pop_front() {
        for &v in g.out(u) {
            if level[v as usize] < 0 {
                level[v as usize] = level[u as usize] + 1;
                q.push_back(v);
            }
        }
    }
    level
}

/// Eccentricity of `src`: max BFS level reached.
pub fn eccentricity(g: &Csr, src: VertexId) -> i64 {
    bfs_levels(g, src).into_iter().max().unwrap_or(0)
}

/// k-core (coreness) decomposition by repeated peeling (undirected
/// semantics: degree = |out| which equals the full degree for undirected
/// CSR graphs).
pub fn coreness(g: &Csr) -> Vec<u32> {
    let n = g.num_vertices();
    let mut deg: Vec<u32> = (0..n as VertexId).map(|v| g.out_deg(v)).collect();
    let mut core = vec![0u32; n];
    let mut removed = vec![false; n];
    let mut remaining = n;
    let mut k = 0u32;
    while remaining > 0 {
        // peel everything with degree <= k
        let mut stack: Vec<VertexId> =
            (0..n as VertexId).filter(|&v| !removed[v as usize] && deg[v as usize] <= k).collect();
        if stack.is_empty() {
            // prune to the next occupied degree
            k = (0..n)
                .filter(|&v| !removed[v])
                .map(|v| deg[v])
                .min()
                .unwrap_or(k + 1);
            continue;
        }
        while let Some(v) = stack.pop() {
            if removed[v as usize] {
                continue;
            }
            removed[v as usize] = true;
            core[v as usize] = k;
            remaining -= 1;
            for &u in g.out(v) {
                if !removed[u as usize] {
                    deg[u as usize] = deg[u as usize].saturating_sub(1);
                    if deg[u as usize] <= k {
                        stack.push(u);
                    }
                }
            }
        }
        k += 1;
    }
    core
}

/// Exact triangle count (undirected; each triangle counted once).
pub fn triangle_count(g: &Csr) -> u64 {
    let n = g.num_vertices();
    let mut count = 0u64;
    for v in 0..n as VertexId {
        for &u in g.out(v) {
            if u <= v {
                continue;
            }
            // intersect N(v) and N(u), counting w > u to fix orientation
            let (mut i, mut j) = (0usize, 0usize);
            let (nv, nu) = (g.out(v), g.out(u));
            while i < nv.len() && j < nu.len() {
                let (a, b) = (nv[i], nu[j]);
                if a == b {
                    if a > u {
                        count += 1;
                    }
                    i += 1;
                    j += 1;
                } else if a < b {
                    i += 1;
                } else {
                    j += 1;
                }
            }
        }
    }
    count
}

/// Brandes betweenness centrality over `sources` (unweighted, directed
/// edges followed forward; undirected CSR graphs work transparently).
pub fn betweenness(g: &Csr, sources: &[VertexId]) -> Vec<f64> {
    let n = g.num_vertices();
    let mut bc = vec![0.0f64; n];
    for &s in sources {
        let mut sigma = vec![0.0f64; n];
        let mut dist = vec![-1i64; n];
        let mut order: Vec<VertexId> = Vec::with_capacity(n);
        sigma[s as usize] = 1.0;
        dist[s as usize] = 0;
        let mut q = VecDeque::from([s]);
        while let Some(u) = q.pop_front() {
            order.push(u);
            for &v in g.out(u) {
                if dist[v as usize] < 0 {
                    dist[v as usize] = dist[u as usize] + 1;
                    q.push_back(v);
                }
                if dist[v as usize] == dist[u as usize] + 1 {
                    sigma[v as usize] += sigma[u as usize];
                }
            }
        }
        let mut delta = vec![0.0f64; n];
        for &w in order.iter().rev() {
            for &v in g.out(w) {
                if dist[v as usize] == dist[w as usize] + 1 {
                    delta[w as usize] +=
                        sigma[w as usize] / sigma[v as usize] * (1.0 + delta[v as usize]);
                }
            }
            if w != s {
                bc[w as usize] += delta[w as usize];
            }
        }
    }
    bc
}

/// Weakly connected components: component id = min vertex id reachable
/// (treating edges as undirected).
pub fn wcc(g: &Csr) -> Vec<VertexId> {
    let n = g.num_vertices();
    // build undirected adjacency view
    let mut comp: Vec<VertexId> = (0..n as VertexId).collect();
    let mut seen = vec![false; n];
    for start in 0..n as VertexId {
        if seen[start as usize] {
            continue;
        }
        // collect the whole weak component with BFS over out+in
        let mut q = VecDeque::from([start]);
        let mut members = vec![start];
        seen[start as usize] = true;
        while let Some(u) = q.pop_front() {
            for &v in g.out(u).iter().chain(g.inn(u).iter()) {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    members.push(v);
                    q.push_back(v);
                }
            }
        }
        let label = *members.iter().min().unwrap();
        for v in members {
            comp[v as usize] = label;
        }
    }
    comp
}

/// Deterministic synthetic edge weight shared by SSSP implementations:
/// both the oracle and the vertex-centric program derive weights from the
/// endpoints, so nothing extra is stored in the graph image.
#[inline]
pub fn edge_weight(u: VertexId, v: VertexId) -> u64 {
    ((u ^ v) % 16) as u64 + 1
}

/// Dijkstra with the synthetic weights (u64::MAX = unreachable).
pub fn sssp(g: &Csr, src: VertexId) -> Vec<u64> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = g.num_vertices();
    let mut dist = vec![u64::MAX; n];
    dist[src as usize] = 0;
    let mut heap = BinaryHeap::from([Reverse((0u64, src))]);
    while let Some(Reverse((d, u))) = heap.pop() {
        if d > dist[u as usize] {
            continue;
        }
        for &v in g.out(u) {
            let nd = d + edge_weight(u, v);
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd, v)));
            }
        }
    }
    dist
}

/// Modularity Q of a community assignment (undirected, unit weights).
pub fn modularity(g: &Csr, community: &[VertexId]) -> f64 {
    let two_m = g.num_edges() as f64; // undirected edges stored twice
    if two_m == 0.0 {
        return 0.0;
    }
    let n = g.num_vertices();
    let mut intra = 0.0f64;
    let mut comm_deg = std::collections::HashMap::<VertexId, f64>::new();
    for v in 0..n as VertexId {
        *comm_deg.entry(community[v as usize]).or_default() += g.out_deg(v) as f64;
        for &u in g.out(v) {
            if community[u as usize] == community[v as usize] {
                intra += 1.0;
            }
        }
    }
    let deg_term: f64 = comm_deg.values().map(|&d| d * d).sum::<f64>() / two_m;
    (intra - deg_term) / two_m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn pagerank_cycle_uniform() {
        let g = Csr::from_edges(10, &gen::cycle(10), true);
        let pr = pagerank(&g, 0.85, 50);
        for &r in &pr {
            assert!((r - 0.1).abs() < 1e-9, "cycle PR must be uniform, got {r}");
        }
    }

    #[test]
    fn pagerank_star_center_dominates() {
        // undirected star: center referenced by all leaves
        let g = Csr::from_edges(20, &gen::star(20), false);
        let pr = pagerank(&g, 0.85, 100);
        assert!(pr[0] > 5.0 * pr[1], "center {} vs leaf {}", pr[0], pr[1]);
    }

    #[test]
    fn bfs_and_eccentricity() {
        let g = Csr::from_edges(5, &gen::path(5), false);
        assert_eq!(bfs_levels(&g, 0), vec![0, 1, 2, 3, 4]);
        assert_eq!(eccentricity(&g, 0), 4);
        assert_eq!(eccentricity(&g, 2), 2);
    }

    #[test]
    fn coreness_clique_plus_tail() {
        // K4 (vertices 0-3) + tail 3-4-5
        let mut edges = gen::complete(4);
        edges.push((3, 4));
        edges.push((4, 5));
        let g = Csr::from_edges(6, &edges, false);
        let core = coreness(&g);
        assert_eq!(&core[0..4], &[3, 3, 3, 3]);
        assert_eq!(core[4], 1);
        assert_eq!(core[5], 1);
    }

    #[test]
    fn triangles_known_counts() {
        let g = Csr::from_edges(4, &gen::complete(4), false);
        assert_eq!(triangle_count(&g), 4); // C(4,3)
        let g5 = Csr::from_edges(5, &gen::complete(5), false);
        assert_eq!(triangle_count(&g5), 10);
        let p = Csr::from_edges(5, &gen::path(5), false);
        assert_eq!(triangle_count(&p), 0);
    }

    #[test]
    fn betweenness_path_middle_max() {
        let g = Csr::from_edges(5, &gen::path(5), false);
        let all: Vec<VertexId> = (0..5).collect();
        let bc = betweenness(&g, &all);
        // middle vertex lies on most shortest paths
        assert!(bc[2] > bc[1] && bc[2] > bc[3]);
        assert!(bc[0] == 0.0 && bc[4] == 0.0);
        // path graph exact: bc[1] = bc[3] = 2*3=... check symmetry instead
        assert!((bc[1] - bc[3]).abs() < 1e-12);
    }

    #[test]
    fn wcc_two_components() {
        let g = Csr::from_edges(6, &[(0, 1), (1, 2), (4, 3)], true);
        let c = wcc(&g);
        assert_eq!(c[0], 0);
        assert_eq!(c[1], 0);
        assert_eq!(c[2], 0);
        assert_eq!(c[3], 3);
        assert_eq!(c[4], 3);
        assert_eq!(c[5], 5);
    }

    #[test]
    fn sssp_prefers_cheap_path() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 3), (0, 2), (2, 3)], true);
        let d = sssp(&g, 0);
        assert_eq!(d[0], 0);
        let via1 = edge_weight(0, 1) + edge_weight(1, 3);
        let via2 = edge_weight(0, 2) + edge_weight(2, 3);
        assert_eq!(d[3], via1.min(via2));
    }

    #[test]
    fn modularity_two_cliques() {
        let edges = gen::two_cliques(8);
        let g = Csr::from_edges(16, &edges, false);
        let split: Vec<VertexId> = (0..16).map(|v| if v < 8 { 0 } else { 1 }).collect();
        let merged = vec![0; 16];
        let q_split = modularity(&g, &split);
        let q_merged = modularity(&g, &merged);
        assert!(q_split > 0.4, "q_split={q_split}");
        assert!(q_merged.abs() < 1e-9);
    }
}
