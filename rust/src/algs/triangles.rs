//! Triangle counting — §4.5: *optimize in-memory operations*.
//!
//! Each vertex fetches the adjacency lists of (a subset of) its neighbors
//! and intersects them with its own list; every optimization in Fig. 7 is
//! a knob here:
//!
//! * [`IntersectStrategy::Scan`] — two-pointer merge over both sorted
//!   lists: `O(|A| + |B|)` per neighbor, brutal against hub lists.
//! * [`IntersectStrategy::Binary`] — binary-search each element of the
//!   smaller list in the larger: `O(|small| log |big|)`.
//! * [`IntersectStrategy::RestartBinary`] — the paper's *restarted*
//!   binary search: both lists ascend, so each search resumes from the
//!   previous hit's offset, shrinking the haystack as it goes.
//! * [`IntersectStrategy::Hash`] — lists longer than a threshold are
//!   loaded into a hash set once per counting vertex and probed in O(1).
//! * [`OrderMode::HighDegree`] — the paper's *reverse ordering*: the
//!   highest-degree endpoint of each triangle does the discovery, so edge
//!   lists are requested for *low*-degree vertices (small reads, better
//!   cache behaviour) instead of hubs.
//!
//! Orientation guarantees each triangle is counted exactly once: the
//! max-rank vertex `v` counts pairs `u, w` of lower-rank neighbors with
//! `rank(w) < rank(u)` and `w ∈ N(u)`.

use std::collections::HashSet;

use crate::engine::{Engine, EngineConfig, EndCtx, RunReport, VertexProgram, WorkerCtx};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::AtomicF64;
use crate::VertexId;

/// Adjacency-list intersection strategy (the Fig. 7 ladder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntersectStrategy {
    /// Two-pointer merge scan.
    Scan,
    /// Per-element binary search of the smaller list in the larger.
    Binary,
    /// Binary search restarted from the previous hit.
    RestartBinary,
    /// Hash-set probing for lists above the threshold, restart-binary
    /// below it.
    Hash {
        /// Degree above which a list is hashed.
        threshold: usize,
    },
}

/// Which endpoint of a triangle does the counting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderMode {
    /// Max-id vertex counts (the naive enumeration order).
    LowId,
    /// Max-degree vertex counts — the paper's reverse ordering: edge
    /// lists are requested for low-degree neighbors only.
    HighDegree,
}

/// Triangle-count configuration.
#[derive(Debug, Clone, Copy)]
pub struct TriangleOptions {
    /// Intersection strategy.
    pub strategy: IntersectStrategy,
    /// Counting-vertex orientation.
    pub order: OrderMode,
    /// Prefetch candidate neighbor lists before intersecting.
    pub prefetch: bool,
    /// Intersect only the lower-rank candidate sublist instead of the
    /// full neighbor list (the "sorted order" optimization: the naive
    /// baseline merges full lists, quadratic on hubs).
    pub prefilter: bool,
}

impl TriangleOptions {
    /// The fully unoptimized baseline of Fig. 7.
    pub fn naive() -> Self {
        TriangleOptions {
            strategy: IntersectStrategy::Scan,
            order: OrderMode::LowId,
            prefetch: false,
            prefilter: false,
        }
    }

    /// All optimizations on (Fig. 7 rightmost bar).
    pub fn graphyti() -> Self {
        TriangleOptions {
            strategy: IntersectStrategy::Hash { threshold: 64 },
            order: OrderMode::HighDegree,
            prefetch: true,
            prefilter: true,
        }
    }
}

/// rank(v) under an order mode; triangles are counted at max rank.
#[inline]
fn rank(order: OrderMode, deg: u32, v: VertexId) -> (u32, VertexId) {
    match order {
        OrderMode::LowId => (0, v),
        OrderMode::HighDegree => (deg, v),
    }
}

/// Count elements in `haystack ∩ needles` with `rank(w) < cap`.
/// Both slices sorted ascending by id.
fn intersect_count(
    needles: &[VertexId],
    haystack: &[VertexId],
    strategy: IntersectStrategy,
    hashed: Option<&HashSet<VertexId>>,
    cap_filter: impl Fn(VertexId) -> bool,
) -> u64 {
    match strategy {
        IntersectStrategy::Scan => {
            let (mut i, mut j, mut c) = (0usize, 0usize, 0u64);
            while i < needles.len() && j < haystack.len() {
                match needles[i].cmp(&haystack[j]) {
                    std::cmp::Ordering::Equal => {
                        if cap_filter(needles[i]) {
                            c += 1;
                        }
                        i += 1;
                        j += 1;
                    }
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                }
            }
            c
        }
        IntersectStrategy::Binary => {
            // search each element of the smaller list in the larger
            let (small, big) = if needles.len() <= haystack.len() {
                (needles, haystack)
            } else {
                (haystack, needles)
            };
            let mut c = 0u64;
            for &w in small {
                if big.binary_search(&w).is_ok() && cap_filter(w) {
                    c += 1;
                }
            }
            c
        }
        IntersectStrategy::RestartBinary => {
            let (small, big) = if needles.len() <= haystack.len() {
                (needles, haystack)
            } else {
                (haystack, needles)
            };
            let mut c = 0u64;
            let mut lo = 0usize; // restart point: both lists ascend
            for &w in small {
                match big[lo..].binary_search(&w) {
                    Ok(p) => {
                        if cap_filter(w) {
                            c += 1;
                        }
                        lo += p + 1;
                    }
                    Err(p) => lo += p,
                }
                if lo >= big.len() {
                    break;
                }
            }
            c
        }
        IntersectStrategy::Hash { .. } => {
            let set = hashed.expect("hash strategy needs a prebuilt set");
            let mut c = 0u64;
            for &w in haystack {
                if cap_filter(w) && set.contains(&w) {
                    c += 1;
                }
            }
            c
        }
    }
}

struct Triangles {
    opts: TriangleOptions,
    count: AtomicF64, // reduce target mirrored here for retrieval
}

impl VertexProgram for Triangles {
    type Msg = ();

    fn edge_request(&self, _v: VertexId) -> EdgeRequest {
        EdgeRequest::Out // undirected image: full neighbor list
    }

    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, edges: &VertexEdges) {
        let my_rank = rank(self.opts.order, ctx.degree(v), v);
        // candidates: lower-rank neighbors (the triangle's other corners)
        let cand: Vec<VertexId> = edges
            .out_neighbors
            .iter()
            .copied()
            .filter(|&u| rank(self.opts.order, ctx.degree(u), u) < my_rank)
            .collect();
        if cand.len() < 2 {
            return;
        }
        if self.opts.prefetch {
            let reqs: Vec<(VertexId, EdgeRequest)> =
                cand.iter().map(|&u| (u, EdgeRequest::Out)).collect();
            ctx.prefetch_edges(&reqs);
        }
        // the needle list: the naive baseline merges the FULL neighbor
        // list every time (quadratic on hubs); the prefilter optimization
        // narrows it to the lower-rank candidates up front
        let needles: &[VertexId] =
            if self.opts.prefilter { &cand } else { &edges.out_neighbors };
        // hash the needle list once if it is big enough
        let hashed: Option<HashSet<VertexId>> = match self.opts.strategy {
            IntersectStrategy::Hash { threshold } if needles.len() >= threshold => {
                Some(needles.iter().copied().collect())
            }
            _ => None,
        };
        let mut local = 0u64;
        for &u in &cand {
            let u_rank = rank(self.opts.order, ctx.degree(u), u);
            let nu = ctx.fetch_edges(u, EdgeRequest::Out);
            // the rank filter keeps the count orientation-unique even
            // when needles span the full neighbor list
            let filter = |w: VertexId| rank(self.opts.order, ctx.degree(w), w) < u_rank;
            local += match (&hashed, self.opts.strategy) {
                (Some(set), _) => intersect_count(
                    needles,
                    &nu.out_neighbors,
                    self.opts.strategy,
                    Some(set),
                    filter,
                ),
                (None, IntersectStrategy::Hash { .. }) => intersect_count(
                    needles,
                    &nu.out_neighbors,
                    IntersectStrategy::RestartBinary,
                    None,
                    filter,
                ),
                (None, s) => intersect_count(needles, &nu.out_neighbors, s, None, filter),
            };
        }
        if local > 0 {
            ctx.reduce_add(0, local as f64);
        }
    }

    fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}

    fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
        self.count.fetch_add(ctx.reduction_add(0));
    }
}

/// Result of a triangle count.
pub struct TriangleResult {
    /// Total triangles (each counted once).
    pub triangles: u64,
    /// Engine + I/O report.
    pub report: RunReport,
}

/// Count triangles on an undirected graph image.
pub fn triangles(
    source: &dyn EdgeSource,
    opts: TriangleOptions,
    cfg: &EngineConfig,
) -> TriangleResult {
    let index = source.index();
    assert!(!index.directed(), "triangle counting expects an undirected image");
    let n = index.num_vertices();
    let prog = Triangles { opts, count: AtomicF64::new(0.0) };
    // only vertices with degree >= 2 can close a triangle
    let active: Vec<VertexId> =
        (0..n as VertexId).filter(|&v| index.out_deg(v) >= 2).collect();
    let report = Engine::run(&prog, source, &active, cfg);
    TriangleResult { triangles: prog.count.load() as u64, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::csr::Csr;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;

    const STRATEGIES: [IntersectStrategy; 4] = [
        IntersectStrategy::Scan,
        IntersectStrategy::Binary,
        IntersectStrategy::RestartBinary,
        IntersectStrategy::Hash { threshold: 4 },
    ];

    fn check_all(n: usize, edges: &[(VertexId, VertexId)]) {
        let csr = Csr::from_edges(n, edges, false);
        let want = oracle::triangle_count(&csr);
        for strategy in STRATEGIES {
            for order in [OrderMode::LowId, OrderMode::HighDegree] {
                for prefetch in [false, true] {
                    let g = MemGraph::from_edges(n, edges, false);
                    let opts = TriangleOptions { strategy, order, prefetch, prefilter: prefetch };
                    let got = triangles(&g, opts, &EngineConfig { workers: 4, ..Default::default() });
                    assert_eq!(
                        got.triangles, want,
                        "strategy={strategy:?} order={order:?} prefetch={prefetch}"
                    );
                }
            }
        }
    }

    #[test]
    fn complete_graphs() {
        check_all(6, &gen::complete(6)); // C(6,3) = 20
        check_all(4, &gen::complete(4));
    }

    #[test]
    fn triangle_free() {
        check_all(20, &gen::path(20));
        check_all(16, &gen::grid_2d(4, 4));
    }

    #[test]
    fn two_cliques() {
        check_all(12, &gen::two_cliques(6));
    }

    #[test]
    fn rmat_graph() {
        let edges = gen::rmat(8, 2500, 77);
        check_all(256, &edges);
    }

    #[test]
    fn intersect_strategies_agree_directly() {
        // unit-level cross-check of intersect_count
        let a: Vec<VertexId> = vec![1, 3, 5, 7, 9, 11, 40];
        let b: Vec<VertexId> = vec![2, 3, 4, 7, 8, 11, 39, 40, 41];
        let accept = |_w: VertexId| true;
        let want = 4; // {3, 7, 11, 40}
        let hs: HashSet<VertexId> = a.iter().copied().collect();
        assert_eq!(intersect_count(&a, &b, IntersectStrategy::Scan, None, accept), want);
        assert_eq!(intersect_count(&a, &b, IntersectStrategy::Binary, None, accept), want);
        assert_eq!(
            intersect_count(&a, &b, IntersectStrategy::RestartBinary, None, accept),
            want
        );
        assert_eq!(
            intersect_count(&a, &b, IntersectStrategy::Hash { threshold: 0 }, Some(&hs), accept),
            want
        );
    }

    #[test]
    fn high_degree_order_fetches_smaller_lists() {
        // on a heavy-tailed graph, HighDegree ordering must move fewer
        // bytes: hubs fetch leaf lists instead of leaves fetching hubs
        let edges = gen::rmat(9, 5000, 41);
        let g1 = MemGraph::from_edges(512, &edges, false);
        let low = triangles(
            &g1,
            TriangleOptions { strategy: IntersectStrategy::Scan, order: OrderMode::LowId, prefetch: false, prefilter: false },
            &EngineConfig::default(),
        );
        let g2 = MemGraph::from_edges(512, &edges, false);
        let high = triangles(
            &g2,
            TriangleOptions { strategy: IntersectStrategy::Scan, order: OrderMode::HighDegree, prefetch: false, prefilter: true },
            &EngineConfig::default(),
        );
        assert_eq!(low.triangles, high.triangles);
    }
}
