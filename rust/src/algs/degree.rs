//! Degree statistics — computed purely from the in-memory index
//! (zero I/O: the index *is* the O(n) SEM state). Library extra; also the
//! seed-selection helper for diameter estimation and BC.

use crate::graph::format::GraphIndex;
use crate::util::Histogram;
use crate::VertexId;

/// Degree distribution summary.
pub struct DegreeStats {
    /// log2-bucketed histogram of total degree.
    pub hist: Histogram,
    /// Max total degree and the vertex achieving it.
    pub max: (VertexId, u32),
    /// Mean total degree.
    pub mean: f64,
}

/// Compute degree stats from the index (no edge I/O).
pub fn degree_stats(index: &GraphIndex) -> DegreeStats {
    let hist = Histogram::new();
    let mut max = (0 as VertexId, 0u32);
    let mut total = 0u64;
    for v in 0..index.num_vertices() as VertexId {
        let d = index.degree(v);
        hist.record(d as u64);
        total += d as u64;
        if d > max.1 {
            max = (v, d);
        }
    }
    DegreeStats { hist, max, mean: total as f64 / index.num_vertices().max(1) as f64 }
}

/// The `k` highest-total-degree vertices, descending.
pub fn top_k_by_degree(index: &GraphIndex, k: usize) -> Vec<VertexId> {
    let mut vs: Vec<VertexId> = (0..index.num_vertices() as VertexId).collect();
    vs.sort_by_key(|&v| std::cmp::Reverse(index.degree(v)));
    vs.truncate(k);
    vs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    #[test]
    fn star_center_wins() {
        let mut b = GraphBuilder::new(10, false);
        b.add_edges(&gen::star(10));
        let img = b.build_ram();
        let s = degree_stats(&img.index);
        assert_eq!(s.max, (0, 9));
        assert!((s.mean - (2.0 * 9.0 / 10.0)).abs() < 1e-12);
        assert_eq!(top_k_by_degree(&img.index, 1), vec![0]);
    }

    #[test]
    fn top_k_ordering() {
        // degrees: v0=3, v1=1, v2=2, v3=2 (directed totals)
        let mut b = GraphBuilder::new(4, true);
        b.add_edges(&[(0, 1), (0, 2), (0, 3), (2, 3)]);
        let img = b.build_ram();
        let top = top_k_by_degree(&img.index, 2);
        assert_eq!(top[0], 0);
        assert!(top[1] == 2 || top[1] == 3);
    }
}
