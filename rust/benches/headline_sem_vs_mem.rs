//! Headline claim — SEM achieves ~80 % of fully in-memory performance
//! at a 20–100× memory reduction (paper §1), plus the cache-size sweep
//! (DESIGN.md §6 ablation).

use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::wcc::wcc;
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};
use graphyti::coordinator::Table;
use graphyti::graph::builder::RamImage;
use graphyti::graph::format::GraphIndex;
use graphyti::graph::source::{EdgeSource, MemGraph};
use graphyti::util::{fmt_bytes, fmt_dur};

fn open_mem(base: &std::path::PathBuf) -> MemGraph {
    let index =
        GraphIndex::decode(&std::fs::read(base.with_extension("gy-idx")).unwrap()).unwrap();
    let mut adj = std::fs::read(base.with_extension("gy-adj")).unwrap();
    if index.header().checksums {
        // drop the checksum footer so the in-memory baseline holds
        // exactly the data bytes a plain image would
        let footer =
            graphyti::graph::format::ChecksumFooter::from_bytes(&adj).unwrap();
        adj.truncate(footer.data_len as usize);
    }
    MemGraph::from_image(RamImage { index, adj })
}

fn main() {
    let scale = bench_scale();
    let (base_d, cfg) = rmat_workload(scale, 16, true, "headline-d");
    let (base_u, _) = rmat_workload(scale, 16, false, "headline-u");
    banner(
        "Headline",
        "SEM vs in-memory: runtime ratio + memory ratio",
        &format!("R-MAT scale {scale}, cache=1/7 adj, io_delay={}us", cfg.io_delay_us),
    );
    let n = 1usize << scale;
    let thr = 1e-3 / n as f64;
    let ecfg = cfg.engine();

    let mut t = Table::new(&["algorithm", "SEM", "in-mem", "SEM/mem", "SEM disk"]);
    let mut sem_total = 0.0;
    let mut mem_total = 0.0;

    // pagerank
    let g = open_sem(&base_d, &cfg);
    let sem = pagerank_push(&g, cfg.alpha, thr, &ecfg);
    let m = open_mem(&base_d);
    let mem = pagerank_push(&m, cfg.alpha, thr, &ecfg);
    sem_total += sem.report.wall.as_secs_f64();
    mem_total += mem.report.wall.as_secs_f64();
    t.row(&[
        "pagerank-push".into(),
        fmt_dur(sem.report.wall),
        fmt_dur(mem.report.wall),
        format!("{:.2}x", sem.report.wall.as_secs_f64() / mem.report.wall.as_secs_f64()),
        fmt_bytes(sem.report.io.bytes_read),
    ]);

    // coreness
    let g = open_sem(&base_u, &cfg);
    let sem_c = coreness(&g, CorenessOptions::graphyti(), &ecfg);
    let m = open_mem(&base_u);
    let mem_c = coreness(&m, CorenessOptions::graphyti(), &ecfg);
    assert_eq!(sem_c.core, mem_c.core);
    sem_total += sem_c.report.wall.as_secs_f64();
    mem_total += mem_c.report.wall.as_secs_f64();
    t.row(&[
        "coreness".into(),
        fmt_dur(sem_c.report.wall),
        fmt_dur(mem_c.report.wall),
        format!("{:.2}x", sem_c.report.wall.as_secs_f64() / mem_c.report.wall.as_secs_f64()),
        fmt_bytes(sem_c.report.io.bytes_read),
    ]);

    // wcc
    let g = open_sem(&base_d, &cfg);
    let (sem_w, sem_r) = wcc(&g, &ecfg);
    let m = open_mem(&base_d);
    let (mem_w, mem_r) = wcc(&m, &ecfg);
    assert_eq!(sem_w, mem_w);
    sem_total += sem_r.wall.as_secs_f64();
    mem_total += mem_r.wall.as_secs_f64();
    t.row(&[
        "wcc".into(),
        fmt_dur(sem_r.wall),
        fmt_dur(mem_r.wall),
        format!("{:.2}x", sem_r.wall.as_secs_f64() / mem_r.wall.as_secs_f64()),
        fmt_bytes(sem_r.io.bytes_read),
    ]);
    t.print();

    let mut fig = FigTable::new();
    fig.add("pagerank-push sem", &sem.report);
    fig.add("pagerank-push mem", &mem.report);
    fig.add("coreness sem", &sem_c.report);
    fig.add("coreness mem", &mem_c.report);
    fig.add("wcc sem", &sem_r);
    fig.add("wcc mem", &mem_r);
    fig.write_json("headline_sem_vs_mem", &format!("rmat s{scale} ef16")).unwrap();

    let g = open_sem(&base_d, &cfg);
    let m = open_mem(&base_d);
    let sem_resident = g.resident_bytes() + cfg.cache_bytes() as u64;
    let mem_resident = m.resident_bytes();
    println!(
        "\nSEM achieves {:.0}% of in-memory performance (paper: ~80%)",
        100.0 * mem_total / sem_total
    );
    println!(
        "memory: SEM {} vs in-memory {} => {:.1}x reduction",
        fmt_bytes(sem_resident),
        fmt_bytes(mem_resident),
        mem_resident as f64 / sem_resident as f64
    );

    // ablation: cache size sweep (pagerank)
    println!("\nablation: page-cache size vs runtime (pagerank-push)");
    let adj_bytes = std::fs::metadata(base_d.with_extension("gy-adj")).unwrap().len() as usize;
    let mut t = Table::new(&["cache", "frac of adj", "wall", "hit ratio", "disk"]);
    for frac in [32usize, 14, 7, 3, 1] {
        let cache = (adj_bytes / frac).max(64 * 4096);
        let mut c = cfg.clone();
        c.cache_mb = cache.div_ceil(1024 * 1024).max(1);
        let g = open_sem(&base_d, &c);
        let r = pagerank_push(&g, c.alpha, thr, &ecfg);
        t.row(&[
            fmt_bytes(c.cache_bytes() as u64),
            format!("1/{frac}"),
            fmt_dur(r.report.wall),
            format!("{:.3}", r.report.io.hit_ratio()),
            fmt_bytes(r.report.io.bytes_read),
        ]);
    }
    t.print();
}
