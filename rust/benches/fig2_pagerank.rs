//! Figure 2 — PR-push vs PR-pull: runtime, read I/O, I/O requests,
//! thread waits (the paper's context-switch proxy).
//!
//! Paper shape: push ≈ 2.2× faster, ≈ 1.8× less read I/O, ≈ 5× fewer
//! read requests.

use graphyti::algs::pagerank::{pagerank_pull, pagerank_push};
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};

fn main() {
    let scale = bench_scale();
    let (base, cfg) = rmat_workload(scale, 16, true, "fig2");
    banner(
        "Figure 2",
        "PR-pull vs PR-push (limit superfluous reads)",
        &format!("R-MAT scale {scale}, directed, cache=1/7 adj, io_delay={}us", cfg.io_delay_us),
    );
    let n = 1usize << scale;
    let thr = 1e-3 / n as f64;

    let mut t = FigTable::new();
    // pull is the baseline (first row)
    let g = open_sem(&base, &cfg);
    let pull = pagerank_pull(&g, cfg.alpha, thr, 500, &cfg.engine());
    t.add("PR-pull (Pregel/Turi)", &pull.report);

    let g = open_sem(&base, &cfg);
    let push = pagerank_push(&g, cfg.alpha, thr, &cfg.engine());
    t.add("PR-push (Graphyti)", &push.report);
    t.print();
    t.write_json("fig2_pagerank", &format!("rmat s{scale} ef16 directed")).unwrap();

    let speedup = pull.report.wall.as_secs_f64() / push.report.wall.as_secs_f64();
    let io_ratio = pull.report.io.logical_bytes as f64 / push.report.io.logical_bytes.max(1) as f64;
    let req_ratio =
        pull.report.io.read_requests as f64 / push.report.io.read_requests.max(1) as f64;
    let wait_ratio =
        pull.report.io.thread_waits as f64 / push.report.io.thread_waits.max(1) as f64;
    println!("\npush vs pull: runtime {speedup:.2}x  read-bytes {io_ratio:.2}x  requests {req_ratio:.2}x  waits {wait_ratio:.2}x");
    println!("paper:        runtime 2.2x   read-bytes 1.8x   requests ~5x");

    // sanity: both converge to the same ranking
    let l1: f64 = push.rank.iter().zip(&pull.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-2, "variants disagree: L1 {l1}");
}
