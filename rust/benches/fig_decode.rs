//! Varint decode fast path — word-level vs scalar delta decoding.
//!
//! Three stream shapes, all encoded with the production
//! `encode_deltas`:
//!
//! 1. **Dense one-byte deltas**: sorted ids with gaps <= 100, the regime
//!    the v2 compression argument rests on. This is where the word path
//!    must win and where the acceptance bar (>= 2x fewer per-byte
//!    operations) applies.
//! 2. **R-MAT scale 12** per-vertex neighbor lists (short, hub-skewed).
//! 3. **R-MAT scale 14** likewise, 4x more vertices.
//!
//! Besides wall-clock MB/s and edges/s, the bench reports a
//! **deterministic per-byte operation model** so the comparison is
//! reproducible on any machine (and meaningful even without a native
//! toolchain producing trustworthy timings):
//!
//! - scalar decoder: 6 ops per input byte (load, cursor increment,
//!   mask, shift-or accumulate, continuation test, loop branch);
//! - word decoder: 6 ops per 8-byte window probe (load, mask,
//!   trailing_zeros, branch, two cursor advances) plus 2 ops per
//!   one-byte delta in the run (shift, mask — the add/push are common
//!   to both paths and cancel); multi-byte deltas and the tail fall
//!   back to scalar cost.
//!
//! The model walks the *actual encoded bytes* with the same control
//! flow as `decode_deltas`, so the counts are exact, not estimates.

use graphyti::coordinator::benchkit::{banner, bench_out_dir, bench_scale};
use graphyti::graph::gen;
use graphyti::graph::varint::{decode_deltas, decode_deltas_scalar, encode_deltas};
use graphyti::util::{bench, fmt_bytes, Json, XorShift};
use graphyti::VertexId;

/// One encoded workload: concatenated per-list delta streams.
struct Workload {
    name: String,
    buf: Vec<u8>,
    /// Value count of each concatenated list, in stream order.
    counts: Vec<usize>,
    total_values: u64,
}

impl Workload {
    fn from_lists(name: &str, lists: &[Vec<VertexId>]) -> Workload {
        let mut buf = Vec::new();
        let mut counts = Vec::new();
        let mut total_values = 0u64;
        for l in lists {
            if l.is_empty() {
                continue;
            }
            counts.push(l.len());
            total_values += l.len() as u64;
            encode_deltas(l, &mut buf);
        }
        Workload { name: name.to_string(), buf, counts, total_values }
    }
}

/// Dense sorted ids, every delta one byte.
fn one_byte_stream(values: usize, seed: u64) -> Vec<Vec<VertexId>> {
    let mut rng = XorShift::new(seed);
    let mut v: u32 = 0;
    let mut out = Vec::with_capacity(values);
    for _ in 0..values {
        v = v.wrapping_add(1 + rng.next_below(100) as u32);
        out.push(v);
    }
    vec![out]
}

/// Per-vertex sorted out-neighbor lists of an R-MAT graph.
fn rmat_lists(scale: u32, edge_factor: usize, seed: u64) -> Vec<Vec<VertexId>> {
    let n = 1usize << scale;
    let mut adj: Vec<Vec<VertexId>> = vec![Vec::new(); n];
    for (u, v) in gen::rmat(scale, n * edge_factor, seed) {
        adj[u as usize].push(v);
    }
    for l in adj.iter_mut() {
        l.sort_unstable();
        l.dedup();
    }
    adj
}

/// Advance past one encoded varint (cursor only).
fn skip_varint(buf: &[u8], p: &mut usize) {
    while buf[*p] & 0x80 != 0 {
        *p += 1;
    }
    *p += 1;
}

/// Exact per-byte operation counts for (scalar, word) under the model
/// in the module docs. Mirrors `decode_deltas`' control flow byte for
/// byte.
fn op_counts(w: &Workload) -> (u64, u64) {
    let scalar = 6 * w.buf.len() as u64;
    let mut word = 0u64;
    let mut p = 0usize;
    for &count in &w.counts {
        let mut i = 0usize;
        while i < count && p + 8 <= w.buf.len() {
            let win = u64::from_le_bytes(w.buf[p..p + 8].try_into().unwrap());
            let conts = win & 0x8080_8080_8080_8080u64;
            let run = if conts == 0 { 8 } else { (conts.trailing_zeros() / 8) as usize };
            if run == 0 {
                let start = p;
                skip_varint(&w.buf, &mut p);
                word += 6 * (p - start) as u64;
                i += 1;
                continue;
            }
            let take = run.min(count - i);
            word += 6 + 2 * take as u64;
            p += take;
            i += take;
        }
        while i < count {
            let start = p;
            skip_varint(&w.buf, &mut p);
            word += 6 * (p - start) as u64;
            i += 1;
        }
    }
    assert_eq!(p, w.buf.len(), "op model must consume the whole stream");
    (scalar, word)
}

fn main() {
    // GRAPHYTI_BENCH_SCALE caps the R-MAT shapes so the CI smoke run
    // stays small; default reproduces the paper-figure sizes 12/14.
    let cap = bench_scale();
    let dense_values = 1usize << cap.min(20);
    let workloads = [
        Workload::from_lists("one-byte-dense", &one_byte_stream(dense_values, 0xD0DE)),
        Workload::from_lists(
            &format!("rmat-s{}", 12.min(cap)),
            &rmat_lists(12.min(cap), 8, 41),
        ),
        Workload::from_lists(
            &format!("rmat-s{}", 14.min(cap)),
            &rmat_lists(14.min(cap), 8, 42),
        ),
    ];

    banner(
        "Decode fast path",
        "word-level varint delta decode vs byte-at-a-time scalar",
        &format!(
            "dense stream {} values; R-MAT ef8 scales {}/{}",
            dense_values,
            12.min(cap),
            14.min(cap)
        ),
    );

    let mut rows = Vec::new();
    for w in &workloads {
        // correctness first: the two decoders must agree on this exact
        // stream before we time anything
        let (mut ps, mut pw) = (0usize, 0usize);
        let (mut outs, mut outw) = (Vec::new(), Vec::new());
        for &c in &w.counts {
            outs.clear();
            outw.clear();
            decode_deltas_scalar(&w.buf, c, &mut ps, &mut outs);
            decode_deltas(&w.buf, c, &mut pw, &mut outw);
            assert_eq!(outs, outw, "{}: decoders diverged", w.name);
            assert_eq!(ps, pw, "{}: cursors diverged", w.name);
        }

        let time_decoder = |label: &str,
                            f: &dyn Fn(&[u8], usize, &mut usize, &mut Vec<VertexId>)| {
            let mut out = Vec::new();
            bench(label, 3, 20, || {
                let mut pos = 0usize;
                for &c in &w.counts {
                    out.clear();
                    f(&w.buf, c, &mut pos, &mut out);
                    std::hint::black_box(&out);
                }
            })
        };
        let scalar_t =
            time_decoder(&format!("{} scalar", w.name), &|b, c, p, o| {
                decode_deltas_scalar(b, c, p, o)
            });
        let word_t = time_decoder(&format!("{} word", w.name), &|b, c, p, o| {
            decode_deltas(b, c, p, o)
        });

        let mbps = |t: &graphyti::util::BenchResult| {
            w.buf.len() as f64 / 1e6 / t.median().as_secs_f64()
        };
        let medges = |t: &graphyti::util::BenchResult| {
            w.total_values as f64 / 1e6 / t.median().as_secs_f64()
        };
        let (ops_scalar, ops_word) = op_counts(w);
        let op_ratio = ops_scalar as f64 / ops_word as f64;

        println!("{}", scalar_t.report());
        println!("{}", word_t.report());
        println!(
            "{:<24} {:>10}  scalar {:>8.1} MB/s {:>8.2} Medges/s | word {:>8.1} MB/s \
             {:>8.2} Medges/s ({:.2}x) | op model {:.2} vs {:.2} ops/byte ({:.2}x fewer)",
            w.name,
            fmt_bytes(w.buf.len() as u64),
            mbps(&scalar_t),
            medges(&scalar_t),
            mbps(&word_t),
            medges(&word_t),
            word_t.speedup_over(&scalar_t),
            ops_scalar as f64 / w.buf.len() as f64,
            ops_word as f64 / w.buf.len() as f64,
            op_ratio,
        );

        for (variant, t, ops) in
            [("scalar", &scalar_t, ops_scalar), ("word", &word_t, ops_word)]
        {
            rows.push(Json::obj(vec![
                ("variant", Json::s(format!("{} {}", w.name, variant))),
                ("wall_ms", Json::f(t.median().as_secs_f64() * 1e3)),
                // bytes decoded: deterministic for a fixed stream, the
                // quantity benchcheck pins alongside wall time
                ("io", Json::obj(vec![("bytes_read", Json::u(w.buf.len() as u64))])),
                ("mb_per_s", Json::f(w.buf.len() as f64 / 1e6 / t.median().as_secs_f64())),
                ("medges_per_s", Json::f(
                    w.total_values as f64 / 1e6 / t.median().as_secs_f64(),
                )),
                ("model_ops", Json::u(ops)),
                ("model_ops_per_byte", Json::f(ops as f64 / w.buf.len() as f64)),
            ]));
        }

        // acceptance bar: on the dense one-byte stream the word decoder
        // must do >= 2x fewer per-byte operations than the scalar one —
        // deterministic, machine-independent
        if w.name == "one-byte-dense" {
            println!(
                "one-byte-dense op-model ratio {:.2}x (require >= 2.0): {}",
                op_ratio,
                if op_ratio >= 2.0 { "PASS" } else { "FAIL" }
            );
            assert!(
                op_ratio >= 2.0,
                "word decoder must model >= 2x fewer per-byte ops on one-byte streams \
                 (got {op_ratio:.2}x)"
            );
        }
    }

    let json = Json::obj(vec![
        ("fig", Json::s("fig_decode")),
        (
            "workload",
            Json::s(format!(
                "dense one-byte {} values + rmat ef8 s{}/s{}; op model: scalar 6/byte, \
                 word 6/window + 2/run-byte, multi-byte falls back to scalar",
                dense_values,
                12.min(cap),
                14.min(cap)
            )),
        ),
        ("schema", Json::u(1)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = bench_out_dir().join("BENCH_fig_decode.json");
    std::fs::write(&path, json.encode_pretty()).unwrap();
    println!("baseline written: {}", path.display());
}
