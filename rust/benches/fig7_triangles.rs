//! Figure 7 — triangle counting: incremental in-memory optimizations.
//! scan → binary search → restarted binary → hash(high-degree) →
//! + degree ordering (reverse enumeration).
//!
//! Paper shape: all optimizations together ≈ two orders of magnitude
//! over the scan baseline.

use graphyti::algs::triangles::{triangles, IntersectStrategy, OrderMode, TriangleOptions};
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};

fn main() {
    // triangle counting is O(sum of deg^2) on hubs; keep scale modest
    let scale = bench_scale().min(13);
    let (base, cfg) = rmat_workload(scale, 16, false, "fig7");
    banner(
        "Figure 7",
        "triangle counting: optimize in-memory operations",
        &format!("R-MAT scale {scale}, undirected, cache=1/7 adj, io_delay={}us", cfg.io_delay_us),
    );

    let ladder: [(&str, TriangleOptions); 5] = [
        (
            "scan (baseline)",
            TriangleOptions { strategy: IntersectStrategy::Scan, order: OrderMode::LowId, prefetch: false, prefilter: false },
        ),
        (
            "+ binary search",
            TriangleOptions { strategy: IntersectStrategy::Binary, order: OrderMode::LowId, prefetch: false, prefilter: false },
        ),
        (
            "+ restarted binary",
            TriangleOptions { strategy: IntersectStrategy::RestartBinary, order: OrderMode::LowId, prefetch: false, prefilter: false },
        ),
        (
            "+ hash high-degree",
            TriangleOptions { strategy: IntersectStrategy::Hash { threshold: 64 }, order: OrderMode::LowId, prefetch: false, prefilter: false },
        ),
        (
            "+ degree ordering (Graphyti)",
            TriangleOptions { strategy: IntersectStrategy::Hash { threshold: 64 }, order: OrderMode::HighDegree, prefetch: true, prefilter: true },
        ),
    ];

    let mut t = FigTable::new();
    let mut counts = Vec::new();
    let mut walls = Vec::new();
    for (label, opts) in ladder {
        let g = open_sem(&base, &cfg);
        let r = triangles(&g, opts, &cfg.engine());
        counts.push(r.triangles);
        walls.push(r.report.wall.as_secs_f64());
        t.add(label, &r.report);
    }
    t.print();
    t.write_json("fig7_triangles", &format!("rmat s{scale} ef16 undirected")).unwrap();
    assert!(counts.windows(2).all(|w| w[0] == w[1]), "all variants must agree: {counts:?}");
    println!(
        "\ntriangles: {}   total speedup scan -> all-optimized: {:.1}x (paper: ~100x)",
        counts[0],
        walls[0] / walls[walls.len() - 1]
    );

    // ablation: hash threshold (DESIGN.md §6)
    println!("\nablation: hash-table degree threshold");
    let mut t = FigTable::new();
    for thr in [8usize, 32, 64, 256, 1024] {
        let g = open_sem(&base, &cfg);
        let opts = TriangleOptions {
            strategy: IntersectStrategy::Hash { threshold: thr },
            order: OrderMode::HighDegree,
            prefetch: true,
            prefilter: true,
        };
        let r = triangles(&g, opts, &cfg.engine());
        t.add(&format!("threshold={thr}"), &r.report);
    }
    t.print();
}
