//! Figure M — message transports: combiner lanes vs queue lanes.
//!
//! The message-phase counterpart of `fig_scaling`: the same program on
//! the dense O(n) combiner lanes and on the recycled queue-lane
//! baseline, at 1/2/8 workers, plus an edge-factor sweep showing that
//! combiner-lane message memory depends on n only (the paper's
//! "minimize message memory", §4.2 / Fig. 3).
//!
//! This bench doubles as the CI tier-2 messaging smoke (run at
//! `GRAPHYTI_BENCH_SCALE=10`): it *asserts* that
//!
//! 1. PageRank's combiner-lane peak message bytes stay within a small
//!    multiple of `n × size_of::<f32>()` (concretely `3 × workers ×
//!    size_of::<f64>()` bytes per vertex — 12 × n×4 B at 2 workers),
//! 2. that peak is bit-identical across edge factors at fixed n
//!    (O(n), not O(m)),
//! 3. both transports produce the same results,
//!
//! and exits nonzero (panics) if any bound breaks.

use std::mem::size_of;

use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::wcc::wcc;
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};
use graphyti::engine::TransportMode;
use graphyti::util::fmt_bytes;

const TRANSPORTS: [(&str, TransportMode); 2] =
    [("queue", TransportMode::Queue), ("combiner", TransportMode::Auto)];

fn main() {
    let scale = bench_scale();
    let n = 1usize << scale;
    let (base, cfg) = rmat_workload(scale, 16, true, "figmsg");
    banner(
        "Figure M",
        "combiner lanes vs queue lanes (minimize message memory)",
        &format!(
            "R-MAT scale {scale}, ef 16, directed, cache=1/7 adj, io_delay={}us",
            cfg.io_delay_us
        ),
    );
    let thr = 1e-3 / n as f64;

    let mut t = FigTable::new();
    let mut pr_ranks: Vec<(usize, TransportMode, Vec<f64>)> = Vec::new();
    for workers in [1usize, 2, 8] {
        for (name, transport) in TRANSPORTS {
            let g = open_sem(&base, &cfg);
            let mut e = cfg.engine();
            e.workers = workers;
            e.transport = transport;
            let r = pagerank_push(&g, cfg.alpha, thr, &e);
            if transport == TransportMode::Auto {
                let bound = (3 * workers * size_of::<f64>() * n) as u64;
                assert!(
                    r.report.engine.peak_msg_bytes <= bound,
                    "PR combiner peak {} exceeds O(n) bound {} (w={workers})",
                    r.report.engine.peak_msg_bytes,
                    bound
                );
                assert!(
                    r.report.engine.combined_msgs > 0,
                    "hub-heavy R-MAT PageRank must fold messages"
                );
                assert_eq!(r.report.engine.msg_allocs, 0, "combiner path never allocates");
            }
            t.add(&format!("PR-push {name} w={workers}"), &r.report);
            pr_ranks.push((workers, transport, r.rank));
        }
    }
    // both transports converge to the same ranking at every worker count
    let baseline = &pr_ranks[0].2;
    for (workers, transport, rank) in &pr_ranks[1..] {
        let l1: f64 = rank.iter().zip(baseline).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 0.05, "PR transports disagree: L1 {l1} (w={workers}, {transport:?})");
    }

    let mut wcc_labels: Option<Vec<graphyti::VertexId>> = None;
    for workers in [1usize, 2, 8] {
        for (name, transport) in TRANSPORTS {
            let g = open_sem(&base, &cfg);
            let mut e = cfg.engine();
            e.workers = workers;
            e.transport = transport;
            let (labels, r) = wcc(&g, &e);
            if transport == TransportMode::Auto {
                let bound = (3 * workers * size_of::<u32>() * n) as u64;
                assert!(
                    r.engine.peak_msg_bytes <= bound,
                    "WCC combiner peak {} exceeds O(n) bound {} (w={workers})",
                    r.engine.peak_msg_bytes,
                    bound
                );
            }
            t.add(&format!("WCC {name} w={workers}"), &r);
            match &wcc_labels {
                None => wcc_labels = Some(labels),
                Some(want) => assert_eq!(
                    &labels, want,
                    "WCC labels must not depend on transport/workers ({name}, w={workers})"
                ),
            }
        }
    }
    t.print();
    t.write_json("fig_messaging", &format!("rmat s{scale} ef16 directed, workers 1/2/8"))
        .unwrap();

    // ---- O(n) vs O(m): fixed n, growing edge factor ------------------
    println!("\nmessage memory vs edge factor (PR-push, combiner lanes, 2 workers):");
    let mut peaks = Vec::new();
    for ef in [8usize, 16] {
        let (base, cfg) = rmat_workload(scale, ef, true, "figmsg");
        let g = open_sem(&base, &cfg);
        let mut e = cfg.engine();
        e.workers = 2;
        let r = pagerank_push(&g, cfg.alpha, thr, &e).report;
        println!(
            "  ef={ef:>2}: peak {} | {} sends, {} folded away, {} delivered",
            fmt_bytes(r.engine.peak_msg_bytes),
            r.engine.send_ops(),
            r.engine.combined_msgs,
            r.engine.deliveries,
        );
        peaks.push(r.engine.peak_msg_bytes);
    }
    assert!(
        peaks.windows(2).all(|w| w[0] == w[1]),
        "combiner message memory must be independent of edge count: {peaks:?}"
    );
    println!("combiner peak message bytes identical across edge factors: O(n), not O(m)");
}
