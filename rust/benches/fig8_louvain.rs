//! Figure 8 — Louvain: Graphyti (metadata aggregation, no graph
//! modification) vs best-case physical materialization (RAM rewrite),
//! with the local-move / aggregation runtime breakdown.
//!
//! Paper shape: Graphyti ≈ 2× faster than the best-case physically
//! modifying implementation.

use graphyti::algs::louvain::{louvain, LouvainMode};
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};
use graphyti::coordinator::Table;
use graphyti::util::fmt_dur;

fn main() {
    let scale = bench_scale();
    let (base, cfg) = rmat_workload(scale, 16, false, "fig8");
    banner(
        "Figure 8",
        "Louvain: avoid graph structure modification",
        &format!("R-MAT scale {scale}, undirected, cache=1/7 adj, io_delay={}us", cfg.io_delay_us),
    );

    let mut t = Table::new(&[
        "variant", "total", "local-moves", "aggregation", "levels", "Q",
    ]);
    let mut totals = Vec::new();
    let mut fig = FigTable::new();
    for (mode, label) in [
        (LouvainMode::Physical, "physical materialization (RAMDisk best case)"),
        (LouvainMode::Graphyti, "Graphyti (metadata + messaging)"),
    ] {
        let g = open_sem(&base, &cfg);
        let start = std::time::Instant::now();
        let r = louvain(&g, mode, 10, &cfg.engine());
        let total = start.elapsed();
        totals.push((label, total, r.modularity));
        fig.add(label, &r.report);
        t.row(&[
            label.to_string(),
            fmt_dur(total),
            fmt_dur(r.local_move_wall),
            fmt_dur(r.aggregate_wall),
            r.levels.to_string(),
            format!("{:.4}", r.modularity),
        ]);
    }
    t.print();
    println!(
        "\nGraphyti vs physical: {:.2}x on aggregation-bound work (paper: 2x overall)",
        totals[0].1.as_secs_f64() / totals[1].1.as_secs_f64()
    );
    println!("note: quality (Q) is equivalent; the win is avoiding the rewrite.");
    fig.write_json("fig8_louvain", &format!("rmat s{scale} ef16 undirected")).unwrap();
}
