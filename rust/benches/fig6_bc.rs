//! Figure 6 — betweenness centrality: multiple uni-source runs vs
//! multi-source (sync) vs multi-source + async, at 1..32 sources:
//! runtime and cache hits per accessed page.
//!
//! Paper shape at 32 sources: async ≈ +10 % over multi, ≈ +40 % over
//! uni; multi+async brings ~4× less data from disk than uni.

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::degree::top_k_by_degree;
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};
use graphyti::graph::source::EdgeSource;
use graphyti::VertexId;

fn main() {
    // BC state is O(n * sources); keep the graph a step smaller
    let scale = bench_scale().min(14);
    let (base, cfg) = rmat_workload(scale, 16, true, "fig6");
    banner(
        "Figure 6",
        "BC: uni vs multi-source vs multi-source+async",
        &format!("R-MAT scale {scale}, directed, cache=1/7 adj, io_delay={}us", cfg.io_delay_us),
    );

    // one flat collector across source counts for the JSON baseline
    let mut all = FigTable::new();
    for nsrc in [8usize, 16, 32] {
        println!("\n--- {nsrc} sources ---");
        let g0 = open_sem(&base, &cfg);
        let sources: Vec<VertexId> = top_k_by_degree(g0.index(), nsrc);
        drop(g0);

        let mut t = FigTable::new();
        let g = open_sem(&base, &cfg);
        let uni = betweenness(&g, &sources, BcVariant::UniSource, &cfg.engine());
        let uni_hits = g.io_stats().snapshot().hit_ratio();
        t.add("uni-source xN", &uni.report);

        let g = open_sem(&base, &cfg);
        let sync = betweenness(&g, &sources, BcVariant::MultiSourceSync, &cfg.engine());
        let sync_hits = g.io_stats().snapshot().hit_ratio();
        t.add("multi-source (sync)", &sync.report);

        let g = open_sem(&base, &cfg);
        let asyn = betweenness(&g, &sources, BcVariant::MultiSourceAsync, &cfg.engine());
        let async_hits = g.io_stats().snapshot().hit_ratio();
        t.add("multi-source + async", &asyn.report);
        t.print();
        all.add(&format!("uni-source xN src={nsrc}"), &uni.report);
        all.add(&format!("multi-source (sync) src={nsrc}"), &sync.report);
        all.add(&format!("multi-source + async src={nsrc}"), &asyn.report);

        println!(
            "cache hit ratio: uni {:.3}  sync {:.3}  async {:.3} (Fig 6a shape: multi >= uni)",
            uni_hits, sync_hits, async_hits
        );
        println!(
            "disk bytes: uni/async = {:.2}x (paper: ~4x at 32 sources)   async vs uni runtime {:.2}x, vs sync {:.2}x",
            uni.report.io.bytes_read as f64 / asyn.report.io.bytes_read.max(1) as f64,
            uni.report.wall.as_secs_f64() / asyn.report.wall.as_secs_f64(),
            sync.report.wall.as_secs_f64() / asyn.report.wall.as_secs_f64(),
        );
        // correctness across variants
        for (i, (a, b)) in uni.bc.iter().zip(&asyn.bc).enumerate() {
            assert!((a - b).abs() < 1e-6 * (1.0 + b.abs()), "bc[{i}] uni {a} vs async {b}");
        }
    }
    all.write_json("fig6_bc", &format!("rmat s{scale} ef16 directed, 8/16/32 sources")).unwrap();
}
