//! Worker-scaling figure — the work-stealing frontier scheduler.
//!
//! Two workloads on the same R-MAT image:
//!
//! 1. **Balanced**: PageRank-push, whose frontier spreads across the id
//!    space — stealing should be rare and scaling should track worker
//!    count.
//! 2. **Adversarially skewed**: a BFS whose frontier is confined to the
//!    low id range (R-MAT concentrates hubs there) — under the old
//!    static partition most workers idled; with chunk stealing the
//!    max/min busy ratio stays bounded and the steal counter shows why.
//!
//! Row schema: workers, pin (each count runs unpinned then core-pinned),
//! wall, speedup vs the first unpinned run, steals, busy ratio, parked
//! wait time, backoff events, disk bytes.

use graphyti::algs::bfs::bfs;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::coordinator::benchkit::{
    banner, bench_scale, rmat_workload, worker_scaling_pinned, FigTable,
};
use graphyti::engine::EngineConfig;

fn main() {
    let scale = bench_scale().min(16);
    let (base, cfg) = rmat_workload(scale, 16, true, "fig-scaling");
    let n = 1usize << scale;
    let counts = [1usize, 2, 4, 8];

    banner(
        "Worker scaling",
        "chunk-claiming + work stealing vs worker count",
        &format!(
            "R-MAT scale {scale}, directed, cache=1/7 adj, io_delay={}us, mode={:?}, \
             fetch_window={}",
            cfg.io_delay_us, cfg.mode, cfg.fetch_window
        ),
    );

    println!("\n-- PageRank-push (balanced frontier) --");
    let thr = 1e-3 / n as f64;
    // derive engine knobs (mode / pull_density / fetch_window /
    // transport) from the workload config so GRAPHYTI_BENCH_MODE and
    // config files reach the engine; trace=on so the JSON baseline
    // carries per-round I/O summaries. Each worker count runs unpinned
    // then core-pinned — results are identical by contract, the table
    // shows what affinity buys in wall/park time.
    let pr_reports = worker_scaling_pinned(&base, &cfg, &counts, |g, w, pin| {
        let ecfg = EngineConfig { workers: w, trace: true, pin_workers: pin, ..cfg.engine() };
        pagerank_push(g, cfg.alpha, thr, &ecfg).report
    });

    println!("\n-- BFS from vertex 0 (skew-prone frontier) --");
    let reports = worker_scaling_pinned(&base, &cfg, &counts, |g, w, pin| {
        let ecfg = EngineConfig { workers: w, trace: true, pin_workers: pin, ..cfg.engine() };
        bfs(g, 0, &ecfg).1
    });

    // reports come back in execution order: each count unpinned then
    // pinned, so doubling the counts list labels them
    let widths: Vec<usize> = counts.iter().flat_map(|&w| [w, w]).collect();
    let variant = |w: usize, pin: bool| if pin { format!("w={w} pinned") } else { format!("w={w}") };
    let mut fig = FigTable::new();
    for (&w, (pin, r)) in widths.iter().zip(&pr_reports) {
        fig.add(&format!("pagerank-push {}", variant(w, *pin)), r);
    }
    for (&w, (pin, r)) in widths.iter().zip(&reports) {
        fig.add(&format!("bfs {}", variant(w, *pin)), r);
    }
    fig.write_json(
        "fig_scaling",
        &format!("rmat s{scale} ef16 directed, workers 1/2/4/8, pinned+unpinned"),
    )
    .unwrap();

    // the scheduler's contract: multi-worker runs stay balanced
    for (pin, r) in &reports[1..] {
        let ratio = r.engine.busy_ratio();
        println!(
            "workers={} pin={}: busy ratio {:.2} ({} steals)",
            r.engine.worker_busy_ns.len(),
            pin,
            ratio,
            r.engine.steals
        );
    }
}
