//! Format bench — v1 fixed-width vs v2 delta+varint images.
//!
//! The SEM thesis is that runtime tracks O(m) edge bytes moved from
//! disk; the v2 format shrinks those bytes ~3x on R-MAT graphs, so a
//! full PageRank or BFS should read proportionally less and (in the
//! I/O-bound regime the injected latency restores) finish faster.
//! Both rows of each table share one cache size (1/7 of the *v1*
//! adjacency) and I/O config — only the on-disk encoding differs.

use graphyti::algs::bfs::bfs;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::coordinator::benchkit::{banner, bench_scale, compare_formats, FigTable};
use graphyti::engine::EngineConfig;

fn main() {
    let scale = bench_scale();
    let n = 1usize << scale;
    let ecfg = EngineConfig::default();

    banner(
        "Format v2",
        "delta+varint adjacency vs fixed u32 — PageRank (push)",
        &format!("R-MAT scale {scale}, directed, cache=1/7 of v1 adj"),
    );
    let thr = 1e-3 / n as f64;
    let pr = compare_formats(scale, 16, true, "fmtpr", |g| {
        pagerank_push(g, 0.85, thr, &ecfg).report
    });

    banner(
        "Format v2",
        "delta+varint adjacency vs fixed u32 — BFS from vertex 0",
        &format!("R-MAT scale {scale}, directed, cache=1/7 of v1 adj"),
    );
    let bf = compare_formats(scale, 16, true, "fmtbfs", |g| bfs(g, 0, &ecfg).1);

    let mut t = FigTable::new();
    t.add("pagerank v1 fixed-u32", &pr.v1);
    t.add("pagerank v2 delta+varint", &pr.v2);
    t.add("bfs v1 fixed-u32", &bf.v1);
    t.add("bfs v2 delta+varint", &bf.v2);
    t.write_json("fig_format_v2", &format!("rmat s{scale} ef16 directed")).unwrap();
}
