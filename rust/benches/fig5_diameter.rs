//! Figure 5 — diameter estimation: uni-source BFS vs parallel
//! multi-source BFS (runtime and I/O).

use graphyti::algs::diameter::{estimate_diameter, DiameterVariant};
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};

fn main() {
    let scale = bench_scale();
    let (base, cfg) = rmat_workload(scale, 16, true, "fig5");
    banner(
        "Figure 5",
        "diameter: uni-source vs multi-source BFS",
        &format!("R-MAT scale {scale}, directed, 32 sweeps, cache=1/7 adj, io_delay={}us", cfg.io_delay_us),
    );

    let mut t = FigTable::new();
    let g = open_sem(&base, &cfg);
    let uni = estimate_diameter(&g, 32, DiameterVariant::UniSource, &cfg.engine());
    t.add("uni-source BFS x32", &uni.report);

    let g = open_sem(&base, &cfg);
    let multi = estimate_diameter(&g, 32, DiameterVariant::MultiSource, &cfg.engine());
    t.add("multi-source BFS (Graphyti)", &multi.report);
    t.print();
    t.write_json("fig5_diameter", &format!("rmat s{scale} ef16 directed, 32 sweeps")).unwrap();

    assert_eq!(uni.diameter, multi.diameter, "estimates must agree");
    println!(
        "\nestimate: {}   multi vs uni: runtime {:.2}x, read-bytes {:.2}x, rounds {:.1}x fewer",
        multi.diameter,
        uni.report.wall.as_secs_f64() / multi.report.wall.as_secs_f64(),
        uni.report.io.logical_bytes as f64 / multi.report.io.logical_bytes.max(1) as f64,
        uni.report.rounds as f64 / multi.report.rounds.max(1) as f64,
    );

    // ablation: multi-source width (DESIGN.md §6)
    println!("\nablation: concurrent-source width");
    let mut t = FigTable::new();
    for width in [1usize, 4, 8, 16, 32, 64] {
        let g = open_sem(&base, &cfg);
        let r = estimate_diameter(&g, width, DiameterVariant::MultiSource, &cfg.engine());
        t.add(&format!("width={width}"), &r.report);
    }
    t.print();
}
