//! Substrate microbenchmarks: the SAFS-substitute in isolation —
//! page-cache hit/miss latency, batch merging, and engine messaging
//! throughput. These are the quantities the perf pass (EXPERIMENTS.md
//! §Perf) iterates on.

use std::sync::Arc;

use graphyti::safs::{IoConfig, IoPool, IoStats, PageCache, SemFile, PAGE_SIZE};
use graphyti::util::{bench, fmt_bytes, XorShift};

fn main() {
    println!("\n=== substrate microbenchmarks ===");

    // workload file: 64 MiB
    let path = std::env::temp_dir().join("graphyti-substrate-bench.dat");
    let len = 64 * 1024 * 1024usize;
    if std::fs::metadata(&path).map(|m| m.len() as usize).unwrap_or(0) != len {
        let mut data = vec![0u8; len];
        let mut rng = XorShift::new(1);
        for b in data.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        std::fs::write(&path, &data).unwrap();
    }

    // --- cache hit path -------------------------------------------------
    let stats = Arc::new(IoStats::new());
    let cache = Arc::new(PageCache::new(32 * 1024 * 1024, stats.clone()));
    let pool = Arc::new(IoPool::new(IoConfig::default(), stats.clone()));
    let f = SemFile::open(&path, cache, pool).unwrap();
    // warm 16 MiB
    f.read(0, 16 * 1024 * 1024).unwrap();
    let mut rng = XorShift::new(2);
    let r = bench("cache-hit read (4 KiB, warm)", 100, 2000, || {
        let page = rng.next_below(4096);
        let got = f.read(page * PAGE_SIZE as u64, PAGE_SIZE).unwrap();
        std::hint::black_box(got);
    });
    println!("{}", r.report());

    // --- cache miss path (cold region, tiny cache) -----------------------
    let stats = Arc::new(IoStats::new());
    let cache = Arc::new(PageCache::new(64 * PAGE_SIZE, stats.clone()));
    let pool = Arc::new(IoPool::new(IoConfig::default(), stats.clone()));
    let f2 = SemFile::open(&path, cache, pool).unwrap();
    let mut off = 0u64;
    let r = bench("cache-miss read (4 KiB, cold)", 10, 1000, || {
        let got = f2.read(off % (len as u64 - PAGE_SIZE as u64), PAGE_SIZE).unwrap();
        off += 257 * PAGE_SIZE as u64; // stride past the cache
        std::hint::black_box(got);
    });
    println!("{}", r.report());

    // --- batched + merged reads ------------------------------------------
    let stats = Arc::new(IoStats::new());
    let cache = Arc::new(PageCache::new(64 * PAGE_SIZE, stats.clone()));
    let pool = Arc::new(IoPool::new(IoConfig { threads: 4, ..Default::default() }, stats.clone()));
    let f3 = SemFile::open(&path, cache, pool).unwrap();
    let mut base = 0u64;
    let r = bench("batch read 64x4KiB contiguous (merged)", 5, 500, || {
        let ranges: Vec<(u64, usize)> =
            (0..64).map(|i| (base + i * PAGE_SIZE as u64, PAGE_SIZE)).collect();
        let got = f3.read_ranges(&ranges).unwrap();
        base = (base + 65 * PAGE_SIZE as u64) % (len as u64 / 2);
        std::hint::black_box(got);
    });
    println!("{}", r.report());
    let s = stats.snapshot();
    println!(
        "  merge effectiveness: {} logical misses -> {} physical reads ({} merged)",
        s.cache_misses, s.physical_reads, s.merged_requests
    );

    // --- scattered batch (no merging possible) ----------------------------
    let stats = Arc::new(IoStats::new());
    let cache = Arc::new(PageCache::new(64 * PAGE_SIZE, stats.clone()));
    let pool = Arc::new(IoPool::new(IoConfig { threads: 4, ..Default::default() }, stats));
    let f4 = SemFile::open(&path, cache, pool).unwrap();
    let mut rng = XorShift::new(3);
    let r = bench("batch read 64x4KiB scattered (parallel)", 5, 500, || {
        let ranges: Vec<(u64, usize)> = (0..64)
            .map(|_| (rng.next_below((len - PAGE_SIZE) as u64 / PAGE_SIZE as u64) * PAGE_SIZE as u64, PAGE_SIZE))
            .collect();
        let got = f4.read_ranges(&ranges).unwrap();
        std::hint::black_box(got);
    });
    println!("{}", r.report());

    println!("\nfile: {} at {}", fmt_bytes(len as u64), path.display());
}
