//! Figure 3 — coreness: unoptimized (p2p, no pruning) vs pruning vs
//! pruning + hybrid messaging, plus the switchover-threshold ablation.
//!
//! Paper shape: pruning+hybrid ≈ 2.3× over pruning alone, ≈ 60× over
//! unoptimized.

use graphyti::algs::coreness::{coreness, CorenessOptions, MessageMode};
use graphyti::coordinator::benchkit::{banner, bench_scale, open_sem, rmat_workload, FigTable};

fn main() {
    let scale = bench_scale().min(15);
    let (base, cfg) = rmat_workload(scale, 16, false, "fig3");
    banner(
        "Figure 3",
        "coreness: minimize messaging + prune computation",
        &format!("R-MAT scale {scale}, undirected, cache=1/7 adj, io_delay={}us", cfg.io_delay_us),
    );

    let mut t = FigTable::new();
    let g = open_sem(&base, &cfg);
    let unopt = coreness(&g, CorenessOptions::unoptimized(), &cfg.engine());
    t.add("unoptimized (p2p, no prune)", &unopt.report);

    let g = open_sem(&base, &cfg);
    let pruned = coreness(&g, CorenessOptions::pruned(), &cfg.engine());
    t.add("pruning (multicast)", &pruned.report);

    let g = open_sem(&base, &cfg);
    let graphyti = coreness(&g, CorenessOptions::graphyti(), &cfg.engine());
    t.add("pruning + hybrid (Graphyti)", &graphyti.report);
    t.print();
    t.write_json("fig3_coreness", &format!("rmat s{scale} ef16 undirected")).unwrap();

    assert_eq!(unopt.core, pruned.core);
    assert_eq!(unopt.core, graphyti.core);
    println!(
        "\nhybrid vs pruned: {:.2}x   graphyti vs unopt: {:.2}x   (paper: 2.3x and 60x)",
        pruned.report.wall.as_secs_f64() / graphyti.report.wall.as_secs_f64(),
        unopt.report.wall.as_secs_f64() / graphyti.report.wall.as_secs_f64()
    );

    // ablation: hybrid switchover threshold (DESIGN.md §6)
    println!("\nablation: hybrid switchover fraction (paper uses 0.10)");
    let mut t = FigTable::new();
    for frac in [0.0, 0.05, 0.10, 0.25, 0.5, 1.0] {
        let g = open_sem(&base, &cfg);
        let opts = CorenessOptions {
            mode: MessageMode::Hybrid,
            prune: true,
            switch_frac: frac,
            scan_activation: false,
        };
        let r = coreness(&g, opts, &cfg.engine());
        t.add(&format!("switch_frac={frac:.2}"), &r.report);
    }
    t.print();
}
