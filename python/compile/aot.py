"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the ``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/load_hlo/ and its README.

Run once at build time (``make artifacts``); Python never executes on the
Rust request path.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_pagerank(n: int) -> str:
    lowered = jax.jit(model.pagerank_step).lower(*model.pagerank_step_spec(n))
    return to_hlo_text(lowered)


def lower_modularity(n: int, c: int) -> str:
    lowered = jax.jit(model.modularity).lower(*model.modularity_spec(n, c))
    return to_hlo_text(lowered)


ARTIFACTS = {
    # name -> thunk producing HLO text
    "pagerank_step_256": lambda: lower_pagerank(256),
    "pagerank_step_512": lambda: lower_pagerank(512),
    "modularity_256": lambda: lower_modularity(256, 64),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = [args.only] if args.only else list(ARTIFACTS)
    for name in names:
        text = ARTIFACTS[name]()
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")


if __name__ == "__main__":
    main()
