"""Layer-2 JAX model: the AOT-compiled compute graphs.

Two computations are lowered to HLO text by ``aot.py`` and executed from the
Rust coordinator through PJRT (see ``rust/src/runtime/``):

  * ``pagerank_step`` — one damped power-iteration step over a padded dense
    operator, s = 8 rank columns at once (multi-source personalized
    PageRank shares the executable). The contraction runs through the
    Layer-1 Pallas tile kernel.
  * ``modularity`` — Louvain modularity Q for a padded dense adjacency and
    community one-hot; the ``A @ S`` product runs through the same Pallas
    kernel.

Shapes are static per artifact (HLO has no dynamic shapes): the Rust side
pads the active subgraph to the artifact size and masks padded rows with
zeros, which both computations are closed under (zero rows/cols contribute
nothing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.spmv import blocked_matmul

# Lane count for the rank matrix: one PageRank vector per lane.
LANES = 8


def pagerank_step(m_norm, r, dangling, uniform, alpha):
    """One damped PageRank step: ``r' = a (M r + u m_d) + (1-a) u``.

    Args / semantics match ``kernels.ref.pagerank_step_ref``; the only
    difference is that the (n, n) x (n, s) contraction is the Pallas
    blocked-matmul kernel instead of ``jnp.dot``.
    """
    spread = blocked_matmul(m_norm, r)
    dangling_mass = jnp.sum(r * dangling, axis=0, keepdims=True)  # (1, s)
    return (alpha * (spread + uniform * dangling_mass) + (1.0 - alpha) * uniform,)


def modularity(adj, onehot, two_m):
    """Louvain modularity Q (see ``kernels.ref.modularity_ref``).

    ``A @ S`` is the Pallas kernel; the rank-1 degree correction stays in
    plain XLA ops (it is O(n*c), negligible next to the O(n^2 c) product).
    """
    k = jnp.sum(adj, axis=1)
    intra = jnp.sum(blocked_matmul(adj, onehot) * onehot)
    ks = jnp.dot(k, onehot)
    return ((intra - jnp.sum(ks * ks) / two_m) / two_m,)


def pagerank_step_spec(n: int):
    """ShapeDtypeStructs for lowering ``pagerank_step`` at size n."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),      # m_norm
        jax.ShapeDtypeStruct((n, LANES), f32),  # r
        jax.ShapeDtypeStruct((n, 1), f32),      # dangling
        jax.ShapeDtypeStruct((n, 1), f32),      # uniform
        jax.ShapeDtypeStruct((), f32),          # alpha
    )


def modularity_spec(n: int, c: int):
    """ShapeDtypeStructs for lowering ``modularity`` at size (n, c)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),  # adj
        jax.ShapeDtypeStruct((n, c), f32),  # onehot
        jax.ShapeDtypeStruct((), f32),      # two_m
    )
