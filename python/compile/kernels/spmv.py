"""Layer-1 Pallas kernel: blocked dense matmul-accumulate.

This is the numeric hot-spot of the AOT-compiled PageRank power-iteration
step (and the Louvain modularity scorer): ``Y = M @ X`` where ``M`` is a
padded dense (column-normalized, transposed) adjacency tile grid and ``X``
holds one column per concurrent source (the paper's multi-source theme —
s = 8 lanes lets the same executable drive multi-source personalized
PageRank).

TPU-idiomatic structure (see DESIGN.md §Hardware-Adaptation):
  * tiles are (BLOCK x BLOCK) with BLOCK = 128 — MXU-aligned, 64 KiB per
    f32 tile, three live tiles = 192 KiB << 16 MiB VMEM;
  * the BlockSpec grid expresses the HBM<->VMEM schedule: grid =
    (rows/BLOCK, cols/BLOCK), the output tile is revisited across the
    contraction dimension and accumulated in VMEM;
  * ``interpret=True`` because the CPU PJRT plugin cannot execute Mosaic
    custom-calls; real-TPU numbers are estimated in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128  # MXU-aligned tile edge


def _matmul_kernel(m_ref, x_ref, o_ref):
    """One (i, k) grid step: o[i] += m[i, k] @ x[k].

    Grid iteration order is row-major, so for a fixed output row-tile ``i``
    all contraction steps ``k`` run consecutively while ``o_ref`` stays
    resident in VMEM — a classic accumulate-in-place schedule.
    """

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        m_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block",))
def blocked_matmul(m: jax.Array, x: jax.Array, *, block: int = BLOCK) -> jax.Array:
    """Compute ``m @ x`` with the Pallas tile kernel.

    Args:
      m: (n, n) f32 — padded dense operator (n must be a multiple of block).
      x: (n, s) f32 — s right-hand-side columns (s multiple of 8).
      block: tile edge; must divide both n and s-padded extents.

    Returns:
      (n, s) f32 product.
    """
    n, n2 = m.shape
    if n != n2:
        raise ValueError(f"m must be square, got {m.shape}")
    if n % block:
        raise ValueError(f"n={n} not a multiple of block={block}")
    s = x.shape[1]
    sblock = min(block, s)
    if s % sblock:
        raise ValueError(f"s={s} not a multiple of sblock={sblock}")

    grid = (n // block, n // block)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, k: (i, k)),
            pl.BlockSpec((block, sblock), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block, sblock), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(m, x)
