"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

Everything here is the *definition* of correct; pytest asserts the Pallas /
model outputs against these with tight tolerances.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(m, x):
    """Oracle for kernels.spmv.blocked_matmul."""
    return jnp.dot(m, x, preferred_element_type=jnp.float32)


def pagerank_step_ref(m_norm, r, dangling, uniform, alpha):
    """Oracle mirroring model.pagerank_step.

    Args:
      m_norm: (n, n) f32 — column-normalized transposed adjacency:
        ``M[u, v] = 1/outdeg(v)`` if edge v->u else 0 (dangling columns 0).
      r: (n, s) f32 — current rank columns (each sums to 1 over real rows).
      dangling: (n, 1) f32 — 1.0 where the vertex is real *and* dangling
        (outdeg 0), else 0.0; padded rows 0.
      uniform: (n, 1) f32 — 1/n_real on real rows, 0 on padded rows (this
        doubles as the real-vertex mask scaled by 1/n_real).
      alpha: () f32 — damping factor.

    Returns: (n, s) f32 next rank columns.
    """
    spread = jnp.dot(m_norm, r, preferred_element_type=jnp.float32)
    dangling_mass = jnp.sum(r * dangling, axis=0, keepdims=True)  # (1, s)
    return alpha * (spread + uniform * dangling_mass) + (1.0 - alpha) * uniform


def modularity_ref(adj, onehot, two_m):
    """Louvain modularity Q (oracle).

    Q = (1/2m) * sum_ij (A_ij - k_i k_j / 2m) * [c_i == c_j]
      = (1/2m) * [ tr(S^T A S) - ||k^T S||^2 / 2m ]

    Args:
      adj: (n, n) f32 symmetric weighted adjacency (padded rows/cols 0).
      onehot: (n, c) f32 community one-hot (padded rows all-zero).
      two_m: () f32 — total weight 2m = sum(adj).
    """
    k = jnp.sum(adj, axis=1)  # (n,)
    intra = jnp.sum(jnp.dot(adj, onehot) * onehot)
    ks = jnp.dot(k, onehot)  # (c,)
    return (intra - jnp.sum(ks * ks) / two_m) / two_m
