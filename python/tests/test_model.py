"""Layer-2 correctness: pagerank_step / modularity vs oracles + invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(0xBEEF)


def _random_graph_operator(n_real, n_pad, rng, edge_p=0.05):
    """Random directed graph -> (m_norm, dangling, uniform, adj) padded."""
    adj = (rng.random((n_real, n_real)) < edge_p).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    outdeg = adj.sum(axis=1)
    m = np.zeros((n_pad, n_pad), np.float32)
    # M[u, v] = A[v, u] / outdeg(v)
    with np.errstate(divide="ignore", invalid="ignore"):
        col = np.where(outdeg > 0, 1.0 / outdeg, 0.0)
    m[:n_real, :n_real] = adj.T * col[None, :]
    dang = np.zeros((n_pad, 1), np.float32)
    dang[:n_real, 0] = (outdeg == 0).astype(np.float32)
    uni = np.zeros((n_pad, 1), np.float32)
    uni[:n_real, 0] = 1.0 / n_real
    return m, dang, uni, adj


def _uniform_rank(n_real, n_pad, s=model.LANES):
    r = np.zeros((n_pad, s), np.float32)
    r[:n_real] = 1.0 / n_real
    return r


@pytest.mark.parametrize("n_real,n_pad", [(100, 256), (256, 256), (400, 512)])
def test_pagerank_step_matches_oracle(n_real, n_pad):
    m, dang, uni, _ = _random_graph_operator(n_real, n_pad, RNG)
    r = _uniform_rank(n_real, n_pad)
    alpha = jnp.float32(0.85)
    (got,) = model.pagerank_step(
        jnp.asarray(m), jnp.asarray(r), jnp.asarray(dang), jnp.asarray(uni), alpha
    )
    want = ref.pagerank_step_ref(
        jnp.asarray(m), jnp.asarray(r), jnp.asarray(dang), jnp.asarray(uni), alpha
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), edge_p=st.sampled_from([0.0, 0.02, 0.2]))
def test_pagerank_step_conserves_mass(seed, edge_p):
    """Rank columns must keep summing to 1 (stochastic operator invariant)."""
    rng = np.random.default_rng(seed)
    n_real, n_pad = 200, 256
    m, dang, uni, _ = _random_graph_operator(n_real, n_pad, rng, edge_p)
    r = _uniform_rank(n_real, n_pad)
    alpha = jnp.float32(0.85)
    for _ in range(3):
        (r,) = model.pagerank_step(
            jnp.asarray(m), jnp.asarray(r), jnp.asarray(dang), jnp.asarray(uni), alpha
        )
        r = np.asarray(r)
        np.testing.assert_allclose(r.sum(axis=0), np.ones(model.LANES), rtol=1e-4)
        assert (r[n_real:] == 0).all(), "padded rows must stay zero"
        assert (r >= 0).all()


def test_pagerank_fixpoint_on_cycle():
    """On a directed cycle the uniform vector is the exact fixpoint."""
    n_real, n_pad = 256, 256
    adj = np.zeros((n_real, n_real), np.float32)
    for v in range(n_real):
        adj[v, (v + 1) % n_real] = 1.0
    m = adj.T.copy()  # outdeg = 1 everywhere
    dang = np.zeros((n_pad, 1), np.float32)
    uni = np.full((n_pad, 1), 1.0 / n_real, np.float32)
    r = _uniform_rank(n_real, n_pad)
    (r2,) = model.pagerank_step(
        jnp.asarray(m), jnp.asarray(r), jnp.asarray(dang), jnp.asarray(uni),
        jnp.float32(0.85),
    )
    np.testing.assert_allclose(np.asarray(r2), r, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("n_real", [64, 200, 256])
def test_modularity_matches_oracle(n_real):
    n_pad, c = 256, 64
    rng = np.random.default_rng(n_real)
    adj_r = (rng.random((n_real, n_real)) < 0.1).astype(np.float32)
    adj_r = np.triu(adj_r, 1)
    adj_r = adj_r + adj_r.T
    adj = np.zeros((n_pad, n_pad), np.float32)
    adj[:n_real, :n_real] = adj_r
    memb = rng.integers(0, c, n_real)
    onehot = np.zeros((n_pad, c), np.float32)
    onehot[np.arange(n_real), memb] = 1.0
    two_m = jnp.float32(adj.sum())
    (got,) = model.modularity(jnp.asarray(adj), jnp.asarray(onehot), two_m)
    want = ref.modularity_ref(jnp.asarray(adj), jnp.asarray(onehot), two_m)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4)


def test_modularity_extremes():
    """Q near max for two perfect cliques split correctly; lower when merged."""
    n_pad, c = 256, 64
    half = 32
    adj = np.zeros((n_pad, n_pad), np.float32)
    adj[:half, :half] = 1.0
    adj[half : 2 * half, half : 2 * half] = 1.0
    np.fill_diagonal(adj, 0.0)
    two_m = jnp.float32(adj.sum())

    split = np.zeros((n_pad, c), np.float32)
    split[:half, 0] = 1.0
    split[half : 2 * half, 1] = 1.0
    merged = np.zeros((n_pad, c), np.float32)
    merged[: 2 * half, 0] = 1.0

    (q_split,) = model.modularity(jnp.asarray(adj), jnp.asarray(split), two_m)
    (q_merged,) = model.modularity(jnp.asarray(adj), jnp.asarray(merged), two_m)
    assert float(q_split) == pytest.approx(0.5, abs=1e-3)
    assert float(q_merged) == pytest.approx(0.0, abs=1e-6)
    assert float(q_split) > float(q_merged)
