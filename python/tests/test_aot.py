"""AOT path: lowering produces parseable HLO text with the right signature,
and the lowered computation (run through jax itself) matches the model."""

import re

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_pagerank_hlo_text_shape_signature():
    text = aot.lower_pagerank(256)
    assert "HloModule" in text
    # 5 parameters with the documented shapes must appear
    assert re.search(r"f32\[256,256\]", text), "missing operator param"
    assert re.search(r"f32\[256,8\]", text), "missing rank param"
    assert re.search(r"f32\[\]", text), "missing alpha scalar"
    # tupled single output
    assert "tuple" in text.lower()


def test_modularity_hlo_text_shape_signature():
    text = aot.lower_modularity(256, 64)
    assert "HloModule" in text
    assert re.search(r"f32\[256,64\]", text)


def test_all_artifacts_lower(tmp_path):
    import subprocess, sys, os

    # exercise the CLI exactly as `make artifacts` does
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "pagerank_step_256"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, env=env,
    )
    assert out.returncode == 0, out.stderr
    f = tmp_path / "pagerank_step_256.hlo.txt"
    assert f.exists() and f.stat().st_size > 1000


def test_lowered_numerics_roundtrip():
    """Compile the lowered stablehlo back through jax and compare outputs —
    guards against lowering-time divergence from the eager model."""
    n = 256
    spec = model.pagerank_step_spec(n)
    lowered = jax.jit(model.pagerank_step).lower(*spec)
    compiled = lowered.compile()
    rng = np.random.default_rng(7)
    m = rng.random((n, n)).astype(np.float32) * 0.01
    r = np.full((n, model.LANES), 1.0 / n, np.float32)
    dang = np.zeros((n, 1), np.float32)
    uni = np.full((n, 1), 1.0 / n, np.float32)
    alpha = np.float32(0.85)
    (got,) = compiled(m, r, dang, uni, alpha)
    (want,) = model.pagerank_step(
        jnp.asarray(m), jnp.asarray(r), jnp.asarray(dang), jnp.asarray(uni),
        jnp.float32(alpha),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
