"""Layer-1 correctness: Pallas blocked matmul vs the pure-jnp oracle.

This is the CORE numeric signal: if the kernel drifts from ref.py, every
artifact the Rust runtime executes is wrong.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.spmv import BLOCK, blocked_matmul
from compile.kernels import ref

RNG = np.random.default_rng(0xC0FFEE)


def _rand(shape, dtype=np.float32, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


@pytest.mark.parametrize("n", [128, 256, 384, 512])
@pytest.mark.parametrize("s", [8, 16, 128])
def test_matmul_matches_ref_grid(n, s):
    m = _rand((n, n))
    x = _rand((n, s))
    got = blocked_matmul(jnp.asarray(m), jnp.asarray(x))
    want = ref.matmul_ref(jnp.asarray(m), jnp.asarray(x))
    # tolerance scales with contraction length (tile-wise accumulation
    # order differs from the oracle's single dot)
    tol = 1e-6 * n
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=tol)


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(min_value=1, max_value=4),
    sb=st.integers(min_value=1, max_value=2),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_matmul_hypothesis_shapes(nb, sb, seed, scale):
    """Sweep block-multiple shapes and magnitudes against the oracle."""
    rng = np.random.default_rng(seed)
    n, s = nb * BLOCK, sb * 8
    m = (rng.standard_normal((n, n)) * scale).astype(np.float32)
    x = (rng.standard_normal((n, s)) * scale).astype(np.float32)
    got = np.asarray(blocked_matmul(jnp.asarray(m), jnp.asarray(x)))
    want = np.asarray(ref.matmul_ref(jnp.asarray(m), jnp.asarray(x)))
    # accumulation-order differences scale with n and magnitude^2
    tol = 3e-5 * scale * scale * n
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=tol)


def test_matmul_rejects_unaligned():
    with pytest.raises(ValueError):
        blocked_matmul(jnp.zeros((100, 100)), jnp.zeros((100, 8)))
    with pytest.raises(ValueError):
        blocked_matmul(jnp.zeros((128, 256)), jnp.zeros((256, 8)))


def test_matmul_zero_and_identity():
    n = 256
    x = jnp.asarray(_rand((n, 8)))
    z = np.asarray(blocked_matmul(jnp.zeros((n, n)), x))
    np.testing.assert_array_equal(z, np.zeros((n, 8), np.float32))
    i = np.asarray(blocked_matmul(jnp.eye(n), x))
    np.testing.assert_allclose(i, np.asarray(x), rtol=1e-6, atol=1e-6)


def test_matmul_block_structure_independence():
    """Same product whether n spans 2 or 4 tiles (padding with zeros)."""
    n, s = 256, 8
    m = _rand((n, n))
    x = _rand((n, s))
    mp = np.zeros((512, 512), np.float32)
    mp[:n, :n] = m
    xp = np.zeros((512, s), np.float32)
    xp[:n] = x
    small = np.asarray(blocked_matmul(jnp.asarray(m), jnp.asarray(x)))
    big = np.asarray(blocked_matmul(jnp.asarray(mp), jnp.asarray(xp)))
    np.testing.assert_allclose(big[:n], small, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(big[n:], np.zeros((512 - n, s), np.float32))
