//! Quickstart: generate a graph, build its on-disk image, run PageRank
//! semi-externally, print the most important vertices.
//!
//!     cargo run --release --example quickstart

use graphyti::algs::pagerank::pagerank_push;
use graphyti::coordinator::RunConfig;
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::gen;
use graphyti::graph::source::SemGraph;

fn main() -> graphyti::Result<()> {
    // 1. synthesize a Twitter-like (heavy-tailed) directed graph
    let scale = 14; // 16k vertices
    let edges = gen::rmat(scale, 1 << (scale + 4), 42);
    let n = 1usize << scale;

    // 2. build the on-disk image: O(n) index + O(m) adjacency file
    let base = std::env::temp_dir().join("graphyti-quickstart");
    let mut b = GraphBuilder::new(n, true);
    b.add_edges(&edges);
    let (idx, adj) = b.build_files(&base)?;
    println!("image built: {} + {}", idx.display(), adj.display());

    // 3. open semi-externally: a small page cache stands between the
    //    algorithms and the adjacency file
    let cfg = RunConfig { cache_mb: 4, ..Default::default() };
    let g = SemGraph::open(&base, cfg.cache_bytes(), cfg.io())?;

    // 4. run Graphyti's PR-push
    let r = pagerank_push(&g, 0.85, 1e-10, &cfg.engine());
    let mut top: Vec<u32> = (0..n as u32).collect();
    top.sort_by(|&a, &b| r.rank[b as usize].partial_cmp(&r.rank[a as usize]).unwrap());
    println!("top 10 vertices by PageRank:");
    for &v in top.iter().take(10) {
        println!("  v{v:<8} rank {:.6}", r.rank[v as usize]);
    }
    println!("\nrun stats: {}", r.report.report());
    Ok(())
}
