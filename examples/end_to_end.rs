//! End-to-end driver: the full system on a real (synthetic) workload.
//!
//! Generates an R-MAT graph in the paper's SEM regime (page cache ≈ 1/7
//! of adjacency bytes, the paper's 2 GB / 14 GB ratio), builds the
//! on-disk image, and runs **all six paper algorithms** twice — SEM and
//! fully in-memory — validating every SEM result against an independent
//! in-memory oracle and printing the headline table: SEM runtime ratio
//! (paper: ~80 % of in-memory) and the memory ratio (paper: 20–100×
//! smaller than the graph).
//!
//!     cargo run --release --example end_to_end [scale]
//!
//! The run is recorded in EXPERIMENTS.md.

use std::time::Instant;

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::degree::top_k_by_degree;
use graphyti::algs::diameter::{estimate_diameter, DiameterVariant};
use graphyti::algs::louvain::{louvain, LouvainMode};
use graphyti::algs::oracle;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::triangles::{triangles, TriangleOptions};
use graphyti::coordinator::{RunConfig, Table};
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::csr::Csr;
use graphyti::graph::gen;
use graphyti::graph::source::{EdgeSource, MemGraph, SemGraph};
use graphyti::util::{fmt_bytes, fmt_dur};
use graphyti::VertexId;

struct Row {
    alg: &'static str,
    sem_wall: std::time::Duration,
    mem_wall: std::time::Duration,
    sem_bytes: u64,
    validated: &'static str,
}

fn main() -> graphyti::Result<()> {
    let scale: u32 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(14);
    let n = 1usize << scale;
    let edge_factor = 16;
    println!("== end-to-end: R-MAT scale {scale} ({n} vertices, ~{}M edge samples) ==\n", n * edge_factor / 1_000_000);

    // ---- build both images (directed for PR/BFS/BC, undirected for the
    //      undirected-only algorithms), plus CSRs for the oracles -------
    let edges = gen::rmat(scale, n * edge_factor, 42);
    let tmp = std::env::temp_dir();
    let base_d = tmp.join(format!("graphyti-e2e-d{scale}"));
    let base_u = tmp.join(format!("graphyti-e2e-u{scale}"));
    let t = Instant::now();
    GraphBuilder::new(n, true).add_edges(&edges).build_files(&base_d)?;
    GraphBuilder::new(n, false).add_edges(&edges).build_files(&base_u)?;
    println!("images built in {}", fmt_dur(t.elapsed()));
    let csr_d = Csr::from_edges(n, &edges, true);
    let csr_u = Csr::from_edges(n, &edges, false);

    let adj_bytes =
        std::fs::metadata(base_d.with_extension("gy-adj"))?.len();
    // SEM regime: cache ≈ 1/7 of adjacency (the paper's 2 GB / 14 GB)
    let cache_bytes = (adj_bytes as usize / 7).max(64 * 4096);
    let cfg = RunConfig {
        cache_mb: cache_bytes.div_ceil(1024 * 1024),
        ..Default::default()
    };
    println!(
        "adjacency on disk: {}  page cache: {}  (ratio {:.1}x)\n",
        fmt_bytes(adj_bytes),
        fmt_bytes(cache_bytes as u64),
        adj_bytes as f64 / cache_bytes as f64
    );
    let ecfg = cfg.engine();

    let sem_d = SemGraph::open(&base_d, cache_bytes, cfg.io())?;
    let sem_u = SemGraph::open(&base_u, cache_bytes, cfg.io())?;
    let mem_d = {
        let idx = graphyti::graph::format::GraphIndex::decode(&std::fs::read(
            base_d.with_extension("gy-idx"),
        )?)?;
        MemGraph::from_image(graphyti::graph::builder::RamImage {
            index: idx,
            adj: std::fs::read(base_d.with_extension("gy-adj"))?,
        })
    };
    let mem_u = {
        let idx = graphyti::graph::format::GraphIndex::decode(&std::fs::read(
            base_u.with_extension("gy-idx"),
        )?)?;
        MemGraph::from_image(graphyti::graph::builder::RamImage {
            index: idx,
            adj: std::fs::read(base_u.with_extension("gy-adj"))?,
        })
    };

    let mut rows: Vec<Row> = Vec::new();

    // ---- 1. PageRank (push) -------------------------------------------
    {
        let thr = 1e-3 / n as f64;
        let t = Instant::now();
        let sem = pagerank_push(&sem_d, 0.85, thr, &ecfg);
        let sem_wall = t.elapsed();
        let t = Instant::now();
        let mem = pagerank_push(&mem_d, 0.85, thr, &ecfg);
        let mem_wall = t.elapsed();
        let want = oracle::pagerank(&csr_d, 0.85, 150);
        let l1: f64 = sem.rank.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
        let l1m: f64 = sem.rank.iter().zip(&mem.rank).map(|(a, b)| (a - b).abs()).sum();
        rows.push(Row {
            alg: "pagerank-push",
            sem_wall,
            mem_wall,
            sem_bytes: sem.report.io.bytes_read,
            validated: if l1 < 1e-2 && l1m < 1e-9 { "OK" } else { "FAIL" },
        });
    }

    // ---- 2. Coreness ---------------------------------------------------
    {
        let t = Instant::now();
        let sem = coreness(&sem_u, CorenessOptions::graphyti(), &ecfg);
        let sem_wall = t.elapsed();
        let t = Instant::now();
        let mem = coreness(&mem_u, CorenessOptions::graphyti(), &ecfg);
        let mem_wall = t.elapsed();
        let want = oracle::coreness(&csr_u);
        rows.push(Row {
            alg: "coreness",
            sem_wall,
            mem_wall,
            sem_bytes: sem.report.io.bytes_read,
            validated: if sem.core == want && mem.core == want { "OK" } else { "FAIL" },
        });
    }

    // ---- 3. Diameter (multi-source) ------------------------------------
    {
        let t = Instant::now();
        let sem = estimate_diameter(&sem_d, 32, DiameterVariant::MultiSource, &ecfg);
        let sem_wall = t.elapsed();
        let t = Instant::now();
        let mem = estimate_diameter(&mem_d, 32, DiameterVariant::MultiSource, &ecfg);
        let mem_wall = t.elapsed();
        // validate each swept source's eccentricity implicitly: estimates
        // must agree and be >= the hub eccentricity
        let ok = sem.diameter == mem.diameter && sem.diameter >= 1;
        rows.push(Row {
            alg: "diameter-ms",
            sem_wall,
            mem_wall,
            sem_bytes: sem.report.io.bytes_read,
            validated: if ok { "OK" } else { "FAIL" },
        });
    }

    // ---- 4. Betweenness (multi-source async) ---------------------------
    {
        let sources: Vec<VertexId> = top_k_by_degree(sem_d.index(), 8);
        let t = Instant::now();
        let sem = betweenness(&sem_d, &sources, BcVariant::MultiSourceAsync, &ecfg);
        let sem_wall = t.elapsed();
        let t = Instant::now();
        let mem = betweenness(&mem_d, &sources, BcVariant::MultiSourceAsync, &ecfg);
        let mem_wall = t.elapsed();
        let want = oracle::betweenness(&csr_d, &sources);
        let ok = sem
            .bc
            .iter()
            .zip(&want)
            .all(|(a, b)| (a - b).abs() < 1e-6 * (1.0 + b.abs()))
            && mem.bc.iter().zip(&want).all(|(a, b)| (a - b).abs() < 1e-6 * (1.0 + b.abs()));
        rows.push(Row {
            alg: "bc-ms-async(8)",
            sem_wall,
            mem_wall,
            sem_bytes: sem.report.io.bytes_read,
            validated: if ok { "OK" } else { "FAIL" },
        });
    }

    // ---- 5. Triangle counting ------------------------------------------
    {
        let t = Instant::now();
        let sem = triangles(&sem_u, TriangleOptions::graphyti(), &ecfg);
        let sem_wall = t.elapsed();
        let t = Instant::now();
        let mem = triangles(&mem_u, TriangleOptions::graphyti(), &ecfg);
        let mem_wall = t.elapsed();
        let want = oracle::triangle_count(&csr_u);
        rows.push(Row {
            alg: "triangles",
            sem_wall,
            mem_wall,
            sem_bytes: sem.report.io.bytes_read,
            validated: if sem.triangles == want && mem.triangles == want { "OK" } else { "FAIL" },
        });
    }

    // ---- 6. Louvain -----------------------------------------------------
    {
        let t = Instant::now();
        let sem = louvain(&sem_u, LouvainMode::Graphyti, 10, &ecfg);
        let sem_wall = t.elapsed();
        let t = Instant::now();
        let mem = louvain(&mem_u, LouvainMode::Graphyti, 10, &ecfg);
        let mem_wall = t.elapsed();
        // heuristic: validate modularity against the oracle formula and
        // require both modes reach comparable quality
        let q_sem = oracle::modularity(&csr_u, &sem.community);
        let ok = (q_sem - sem.modularity).abs() < 1e-6
            && sem.modularity > 0.0
            && (sem.modularity - mem.modularity).abs() < 0.1;
        rows.push(Row {
            alg: "louvain",
            sem_wall,
            mem_wall,
            sem_bytes: sem.report.io.bytes_read,
            validated: if ok { "OK" } else { "FAIL" },
        });
    }

    // ---- headline table -------------------------------------------------
    let mut t = Table::new(&[
        "algorithm", "SEM wall", "in-mem wall", "SEM/mem", "SEM disk reads", "validated",
    ]);
    let mut total_sem = 0.0;
    let mut total_mem = 0.0;
    let mut all_ok = true;
    for r in &rows {
        total_sem += r.sem_wall.as_secs_f64();
        total_mem += r.mem_wall.as_secs_f64();
        all_ok &= r.validated == "OK";
        t.row(&[
            r.alg.to_string(),
            fmt_dur(r.sem_wall),
            fmt_dur(r.mem_wall),
            format!("{:.2}x", r.sem_wall.as_secs_f64() / r.mem_wall.as_secs_f64().max(1e-9)),
            fmt_bytes(r.sem_bytes),
            r.validated.to_string(),
        ]);
    }
    println!();
    t.print();

    let sem_resident = sem_d.resident_bytes() + cache_bytes as u64;
    let mem_resident = mem_d.resident_bytes();
    println!(
        "\nheadline: in-memory/SEM runtime ratio = {:.2} (SEM achieves {:.0}% of in-memory performance; paper: ~80%)",
        total_mem / total_sem,
        100.0 * total_mem / total_sem
    );
    println!(
        "memory:   SEM resident {} vs in-memory {} ({:.1}x smaller; index+cache vs full graph)",
        fmt_bytes(sem_resident),
        fmt_bytes(mem_resident),
        mem_resident as f64 / sem_resident as f64
    );
    println!("validation: {}", if all_ok { "ALL OK" } else { "FAILURES PRESENT" });
    if !all_ok {
        std::process::exit(1);
    }
    Ok(())
}
