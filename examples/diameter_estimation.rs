//! Diameter estimation on a road-like grid — §4.3's uni- vs multi-source
//! comparison on the graph class where it matters most (high diameter,
//! narrow frontiers).
//!
//!     cargo run --release --example diameter_estimation

use graphyti::algs::diameter::{estimate_diameter, DiameterVariant};
use graphyti::coordinator::{RunConfig, Table};
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::gen;
use graphyti::graph::source::SemGraph;
use graphyti::util::{fmt_bytes, fmt_dur};

fn main() -> graphyti::Result<()> {
    // 180x180 grid: true diameter = 358
    let side = 180;
    let edges = gen::grid_2d(side, side);
    let n = side * side;
    let base = std::env::temp_dir().join("graphyti-diameter");
    let mut b = GraphBuilder::new(n, false);
    b.add_edges(&edges);
    b.build_files(&base)?;

    let cfg = RunConfig { cache_mb: 1, ..Default::default() };
    let mut t = Table::new(&[
        "variant", "sweeps", "estimate", "wall", "rounds", "read reqs", "edge bytes",
    ]);
    for (variant, label) in [
        (DiameterVariant::UniSource, "uni-source"),
        (DiameterVariant::MultiSource, "multi-source"),
    ] {
        let g = SemGraph::open(&base, cfg.cache_bytes(), cfg.io())?;
        let r = estimate_diameter(&g, 16, variant, &cfg.engine());
        t.row(&[
            label.to_string(),
            r.sources.len().to_string(),
            r.diameter.to_string(),
            fmt_dur(r.report.wall),
            r.report.rounds.to_string(),
            r.report.io.read_requests.to_string(),
            fmt_bytes(r.report.io.logical_bytes),
        ]);
    }
    println!("diameter estimation, {side}x{side} grid (true diameter {}):", 2 * (side - 1));
    t.print();
    println!("\nmulti-source BFS shares each fetched edge list across all");
    println!("concurrent searches and pays far fewer global barriers.");
    Ok(())
}
