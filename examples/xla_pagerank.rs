//! The AOT JAX/Pallas path as a first-class numeric engine: load the
//! `pagerank_step` artifact through PJRT, run dense-block power
//! iteration from Rust, and cross-check the SEM vertex-centric result.
//! Python is nowhere on this path — `make artifacts` already lowered the
//! model.
//!
//!     make artifacts && cargo run --release --example xla_pagerank

use std::sync::Arc;

use graphyti::algs::oracle;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::coordinator::RunConfig;
use graphyti::graph::csr::Csr;
use graphyti::graph::gen;
use graphyti::graph::source::MemGraph;
use graphyti::runtime::{ModularityXla, PageRankXla, XlaRuntime};

fn main() -> graphyti::Result<()> {
    let n = 512;
    let edges = gen::rmat(9, 6000, 2024);
    let csr = Csr::from_edges(n, &edges, true);

    let rt = Arc::new(XlaRuntime::new()?);
    println!("PJRT platform: {}", rt.platform());

    // dense-block PageRank through the Pallas tile kernel (AOT)
    let t = std::time::Instant::now();
    let xla_rank = PageRankXla::new(rt.clone()).pagerank(&csr, 0.85, 80)?;
    println!("XLA dense-block pagerank (80 iters): {:?}", t.elapsed());

    // SEM vertex-centric PR-push on the same graph
    let g = MemGraph::from_edges(n, &edges, true);
    let cfg = RunConfig::default();
    let sem = pagerank_push(&g, 0.85, 1e-12, &cfg.engine());

    // and the plain Rust oracle
    let want = oracle::pagerank(&csr, 0.85, 80);

    let l1 = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    };
    println!("L1(xla, oracle)      = {:.3e}", l1(&xla_rank, &want));
    println!("L1(sem-push, oracle) = {:.3e}", l1(&sem.rank, &want));
    println!("L1(xla, sem-push)    = {:.3e}", l1(&xla_rank, &sem.rank));
    assert!(l1(&xla_rank, &sem.rank) < 1e-3, "three engines must agree");

    // bonus: modularity scoring via the second artifact
    let un = 256;
    let cedges = gen::two_cliques(un / 2);
    let cg = Csr::from_edges(un, &cedges, false);
    let split: Vec<u32> = (0..un as u32).map(|v| if (v as usize) < un / 2 { 0 } else { 1 }).collect();
    let q = ModularityXla::new(rt).score(&cg, &split)?;
    println!("XLA modularity of two-clique split: Q = {q:.4} (expected ~0.5)");
    println!("all engines agree — the AOT artifact is faithful");
    Ok(())
}
