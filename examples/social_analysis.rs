//! Social-network analysis pipeline — the workload class the paper's
//! introduction motivates: community structure, influencer detection and
//! cohesion metrics on a preferential-attachment graph, all running
//! semi-externally under a small page cache.
//!
//!     cargo run --release --example social_analysis

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::degree::top_k_by_degree;
use graphyti::algs::louvain::{louvain, LouvainMode};
use graphyti::algs::triangles::{triangles, TriangleOptions};
use graphyti::coordinator::{RunConfig, Table};
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::gen;
use graphyti::graph::source::{EdgeSource, SemGraph};

fn main() -> graphyti::Result<()> {
    // a Barabási–Albert "social" graph: 8k members, 8 friendships each
    let n = 8192;
    let edges = gen::barabasi_albert(n, 8, 7);
    let base = std::env::temp_dir().join("graphyti-social");
    let mut b = GraphBuilder::new(n, false);
    b.add_edges(&edges);
    b.build_files(&base)?;

    let cfg = RunConfig { cache_mb: 2, ..Default::default() };
    let g = SemGraph::open(&base, cfg.cache_bytes(), cfg.io())?;
    let ecfg = cfg.engine();

    println!("== community detection (Louvain, metadata aggregation) ==");
    let lv = louvain(&g, LouvainMode::Graphyti, 10, &ecfg);
    let ncomm = {
        let mut c = lv.community.clone();
        c.sort_unstable();
        c.dedup();
        c.len()
    };
    println!("{} communities, modularity Q = {:.4}", ncomm, lv.modularity);

    println!("\n== influencers (multi-source async betweenness) ==");
    let sources = top_k_by_degree(g.index(), 16);
    let bc = betweenness(&g, &sources, BcVariant::MultiSourceAsync, &ecfg);
    let mut top: Vec<u32> = (0..n as u32).collect();
    top.sort_by(|&a, &b| bc.bc[b as usize].partial_cmp(&bc.bc[a as usize]).unwrap());
    let mut t = Table::new(&["vertex", "betweenness", "degree", "community"]);
    for &v in top.iter().take(8) {
        t.row(&[
            format!("v{v}"),
            format!("{:.1}", bc.bc[v as usize]),
            g.index().degree(v).to_string(),
            lv.community[v as usize].to_string(),
        ]);
    }
    t.print();

    println!("\n== cohesion (triangles + k-core) ==");
    let tri = triangles(&g, TriangleOptions::graphyti(), &ecfg);
    let core = coreness(&g, CorenessOptions::graphyti(), &ecfg);
    let kmax = core.core.iter().copied().max().unwrap_or(0);
    println!("triangles: {}   max coreness: {kmax}", tri.triangles);

    println!("\nSEM I/O for the whole pipeline: {}", g.io_stats().snapshot().report());
    Ok(())
}
